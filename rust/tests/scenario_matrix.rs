//! Scenario-matrix harness battery: golden worker-count determinism of
//! the report bytes, report/INDEX emission, the registry ↔
//! `docs/SCENARIOS.md` catalogue lockstep, and the availability-trace
//! scenarios actually shaping runs (diurnal thins sync rounds,
//! flash-crowd gates the early fleet, churn drops in-flight uploads).
//! Everything runs on the native-exec FC manifest — no compiled
//! artifacts required.

use std::path::PathBuf;

use feddd::coordinator::run_experiment;
use feddd::runtime::write_native_manifest;
use feddd::scenarios::{
    by_name, registry, run_matrix, write_report, Cell, MatrixReport, MatrixSpec, Tier,
    MATRIX_SCHEMES,
};

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_scenario_matrix_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn smoke_spec(dir: &PathBuf, workers: usize) -> MatrixSpec {
    MatrixSpec {
        tier: Tier::Smoke,
        label: "golden".into(),
        scenarios: vec!["baseline_iid".into(), "churn".into()],
        schemes: vec!["feddd".into()],
        seeds: vec![17],
        workers,
        artifacts_dir: dir.to_string_lossy().into_owned(),
    }
}

#[test]
fn report_bytes_are_identical_across_worker_counts() {
    // The determinism contract from DESIGN.md §Scenario-Matrix: a cell is
    // a pure function of (scenario, scheme, seed, tier), so the whole
    // report — JSON bytes included — must not depend on the worker count.
    let dir = native_dir("golden");
    let a = run_matrix(&smoke_spec(&dir, 1)).unwrap();
    let b = run_matrix(&smoke_spec(&dir, 4)).unwrap();
    assert_eq!(a.cells.len(), 2);
    let ja = a.to_json_string();
    let jb = b.to_json_string();
    assert_eq!(ja, jb, "matrix report bytes differ between workers 1 and 4");
    // and the bytes round-trip: parse back to the same cells
    let back = MatrixReport::from_json(&feddd::util::json::parse(&ja).unwrap()).unwrap();
    assert_eq!(back.cells, a.cells);
    // a smoke run actually trains: the baseline cell beats chance
    assert!(a.cells[0].accuracy > 0.15, "baseline cell at chance: {}", a.cells[0].accuracy);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn write_report_emits_json_markdown_and_regenerates_index() {
    let out = std::env::temp_dir().join(format!("feddd_matrix_reports_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&out);
    let cell = Cell {
        scenario: "baseline_iid".into(),
        scheme: "feddd".into(),
        tier: "smoke".into(),
        seed: 17,
        rounds: 6,
        accuracy: 0.5,
        rare_accuracy: None,
        uploaded_bytes: 100,
        wire_bytes: 120,
        v_time: 10.0,
        mean_staleness: 0.0,
        mean_stragglers: 0.0,
        mean_participants: 8.0,
        churned: 0,
        peak_client_state_bytes: 1000,
    };
    let mk = |label: &str| MatrixReport {
        tier: "smoke".into(),
        label: label.into(),
        scenarios: vec!["baseline_iid".into()],
        schemes: vec!["feddd".into()],
        seeds: vec![17],
        cells: vec![cell.clone()],
    };
    let p1 = write_report(&out, &mk("beta")).unwrap();
    assert!(p1.exists());
    assert!(out.join("MATRIX_smoke_beta.md").exists());
    let idx = std::fs::read_to_string(out.join("INDEX.md")).unwrap();
    assert!(idx.contains("MATRIX_smoke_beta"), "{idx}");
    // a second report regenerates the index with both rows, filename-sorted
    write_report(&out, &mk("alpha")).unwrap();
    let idx = std::fs::read_to_string(out.join("INDEX.md")).unwrap();
    let a = idx.find("MATRIX_smoke_alpha").expect("alpha row");
    let b = idx.find("MATRIX_smoke_beta").expect("beta row");
    assert!(a < b, "index rows not filename-sorted:\n{idx}");
    // loading what we wrote gives back the same cells
    let back = MatrixReport::load(&p1).unwrap();
    assert_eq!(back.cells, vec![cell]);
    let _ = std::fs::remove_dir_all(&out);
}

#[test]
fn catalogue_documents_every_registered_scenario() {
    // docs/SCENARIOS.md and the registry move in lockstep: every
    // registered name must have a `## \`name\`` heading in the catalogue.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("docs/SCENARIOS.md");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing catalogue {}: {e}", path.display()));
    for sc in registry() {
        let heading = format!("## `{}`", sc.name);
        assert!(
            text.contains(&heading),
            "scenario {:?} is registered but has no {heading:?} entry in docs/SCENARIOS.md",
            sc.name
        );
    }
    // The scheme axis moves in lockstep too: every scheme the matrix
    // crosses scenarios with must be named (backticked) in the catalogue,
    // so adding a baseline without documenting it fails here.
    for scheme in MATRIX_SCHEMES {
        let tag = format!("`{scheme}`");
        assert!(
            text.contains(&tag),
            "scheme {scheme:?} is in MATRIX_SCHEMES but never mentioned in docs/SCENARIOS.md"
        );
    }
}

#[test]
fn matrix_runs_every_scheme_end_to_end() {
    // The full scheme axis — selection baselines and the dropout family
    // alike — must survive the same harness: every cell trains, evaluates
    // and accounts bytes. Also pins the headline communication story:
    // `fed_dropout` at its default rate moves strictly fewer wire bytes
    // than `fedavg` on the identical scenario and seed.
    let dir = native_dir("zoo");
    let spec = MatrixSpec {
        tier: Tier::Smoke,
        label: "zoo".into(),
        scenarios: vec!["baseline_iid".into()],
        schemes: MATRIX_SCHEMES.iter().map(|s| s.to_string()).collect(),
        seeds: vec![17],
        workers: 2,
        artifacts_dir: dir.to_string_lossy().into_owned(),
    };
    let rep = run_matrix(&spec).unwrap();
    assert_eq!(rep.cells.len(), MATRIX_SCHEMES.len());
    for cell in &rep.cells {
        assert!(cell.rounds > 0, "{}: no rounds ran", cell.scheme);
        assert!(
            cell.accuracy.is_finite() && cell.accuracy > 0.0,
            "{}: accuracy {} is not a trained model",
            cell.scheme,
            cell.accuracy
        );
        assert!(cell.wire_bytes > 0, "{}: no bytes crossed the wire", cell.scheme);
    }
    let wire = |name: &str| {
        rep.cells.iter().find(|c| c.scheme == name).map(|c| c.wire_bytes).unwrap()
    };
    assert!(
        wire("fed_dropout") < wire("fedavg"),
        "fed_dropout ({}) must shave wire bytes vs fedavg ({}) at the default rate",
        wire("fed_dropout"),
        wire("fedavg")
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn diurnal_trace_thins_sync_rounds_without_emptying_them() {
    // The diurnal trace keeps a rolling half of the fleet online; under
    // the sync engine that caps every round's participants strictly
    // between 0 and n_clients.
    let dir = native_dir("diurnal");
    let mut cfg = by_name("diurnal").unwrap().config(Tier::Smoke, 17);
    cfg.round_mode = "sync".into();
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    let res = run_experiment(cfg.clone()).unwrap();
    for r in &res.rounds {
        assert!(r.participants > 0, "round {} went empty", r.round);
        assert!(
            r.participants < cfg.n_clients,
            "round {} saw the full fleet despite the diurnal trace",
            r.round
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flash_crowd_gates_the_early_fleet_to_the_vanguard() {
    // Before v-time reaches the trace period only the ~10% vanguard is
    // online: the first round can fold at most that many uploads.
    let dir = native_dir("flash");
    let mut cfg = by_name("flash_crowd").unwrap().config(Tier::Smoke, 17);
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    let vanguard = (0..cfg.n_clients).filter(|n| n * 10 < cfg.n_clients).count();
    let res = run_experiment(cfg.clone()).unwrap();
    let first = res.rounds.first().unwrap();
    assert!(
        first.participants <= vanguard,
        "round 1 folded {} uploads with a {vanguard}-client vanguard",
        first.participants
    );
    assert!(first.participants < cfg.n_clients);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn churn_trace_drops_in_flight_uploads() {
    let dir = native_dir("churn");
    let mut cfg = by_name("churn").unwrap().config(Tier::Smoke, 17);
    cfg.churn_rate = 0.9; // aggressive so a 6-round smoke run must see drops
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    let res = run_experiment(cfg).unwrap();
    assert!(
        res.total_churned() > 0,
        "no uploads churned at rate 0.9 over {} rounds",
        res.rounds.len()
    );
    // churn at the default 20% stays deterministic run-to-run (same seed)
    let mut c2 = by_name("churn").unwrap().config(Tier::Smoke, 17);
    c2.artifacts_dir = dir.to_string_lossy().into_owned();
    let a = run_experiment(c2.clone()).unwrap();
    let b = run_experiment(c2).unwrap();
    assert_eq!(a.total_churned(), b.total_churned());
    assert_eq!(a.final_accuracy().unwrap().to_bits(), b.final_accuracy().unwrap().to_bits());
    let _ = std::fs::remove_dir_all(&dir);
}
