//! Loopback serve equivalence: a `serve` coordinator plus `agent`
//! replicas on 127.0.0.1 must reproduce the in-process run **bitwise** —
//! per-round losses, uploaded/wire bytes, virtual-time accounting, eval
//! metrics and the final global parameters — under both round modes.
//!
//! `client_state_bytes` is deliberately *not* compared: the server-side
//! replica folds envelopes with `residual: None` (residuals stay on the
//! agents), so its bookkeeping of virtualized client state differs even
//! though every model/metric byte matches (DESIGN.md §Serve).

use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::metrics::RunResult;
use feddd::runtime::write_native_manifest;
use feddd::tensor::Tensor;
use feddd::transport::{run_agent, AgentOpts, AgentReport, BoundServer, ServeOpts};

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_serve_loopback_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(scheme: &str, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.n_clients = 4;
    cfg.rounds = 4;
    cfg.local_steps = 2;
    cfg.batch = 16;
    cfg.test_n = 64;
    cfg.train_per_client = 60;
    cfg.eval_every = 2;
    cfg.workers = 2;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn serve_opts(cfg: &ExpConfig) -> ServeOpts {
    let mut opts = ServeOpts::from_config(cfg);
    opts.listen = "127.0.0.1:0".into();
    opts.accept_timeout = Duration::from_secs(30);
    opts.round_timeout = Duration::from_secs(120);
    opts
}

/// Run `cfg` through real sockets: bind, spawn one agent thread per
/// `(slot_start, slot_count)` split, then drive the rounds server-side.
fn loopback(
    cfg: &ExpConfig,
    splits: &[(usize, Option<usize>)],
) -> (RunResult, Vec<Tensor>, Vec<AgentReport>) {
    let opts = serve_opts(cfg);
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();
    let handles: Vec<_> = splits
        .iter()
        .map(|&(slot_start, slot_count)| {
            let agent = AgentOpts {
                connect: addr.clone(),
                slot_start,
                slot_count,
                // Host-local override: a different worker count on the
                // agent must not change a single bit.
                overrides: vec![("workers".into(), "1".into())],
            };
            thread::spawn(move || run_agent(&agent).unwrap())
        })
        .collect();
    let coordinator = bound.accept_agents(&opts, cfg).unwrap();
    let mut run = FedRun::with_transport(cfg.clone(), Box::new(coordinator)).unwrap();
    let result = run.run().unwrap();
    run.shutdown_transport().unwrap();
    let reports = handles.into_iter().map(|h| h.join().unwrap()).collect();
    (result, run.global_params.clone(), reports)
}

fn in_process(cfg: &ExpConfig) -> (RunResult, Vec<Tensor>) {
    let mut run = FedRun::new(cfg.clone()).unwrap();
    let result = run.run().unwrap();
    (result, run.global_params.clone())
}

fn assert_bitwise_equal(
    (ra, pa): (&RunResult, &[Tensor]),
    (rb, pb): (&RunResult, &[Tensor]),
    ctx: &str,
) {
    assert_eq!(ra.rounds.len(), rb.rounds.len(), "{ctx}: round count");
    for (x, y) in ra.rounds.iter().zip(&rb.rounds) {
        let t = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{ctx}: round {t} loss");
        assert_eq!(x.uploaded_bytes, y.uploaded_bytes, "{ctx}: round {t} uploaded");
        assert_eq!(x.wire_bytes, y.wire_bytes, "{ctx}: round {t} wire bytes");
        assert_eq!(x.participants, y.participants, "{ctx}: round {t} participants");
        assert_eq!(x.duration.to_bits(), y.duration.to_bits(), "{ctx}: round {t} duration");
        assert_eq!(x.v_time.to_bits(), y.v_time.to_bits(), "{ctx}: round {t} v_time");
        assert_eq!(
            x.mean_dropout.to_bits(),
            y.mean_dropout.to_bits(),
            "{ctx}: round {t} dropout"
        );
        assert_eq!(x.full_broadcast, y.full_broadcast, "{ctx}: round {t} broadcast");
    }
    assert_eq!(ra.evals.len(), rb.evals.len(), "{ctx}: eval count");
    for (x, y) in ra.evals.iter().zip(&rb.evals) {
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{ctx}: eval accuracy");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{ctx}: eval loss");
    }
    assert_eq!(pa.len(), pb.len(), "{ctx}: param arity");
    for (i, (x, y)) in pa.iter().zip(pb).enumerate() {
        assert_eq!(x.data(), y.data(), "{ctx}: global param tensor {i}");
    }
}

#[test]
fn sync_loopback_matches_in_process_bitwise() {
    let dir = native_dir("sync");
    let c = cfg("feddd", &dir);
    let local = in_process(&c);
    // Two agents, slots 0-1 and 2-3 (the second claims "the rest").
    let (result, params, reports) = loopback(&c, &[(0, Some(2)), (2, None)]);
    assert_bitwise_equal((&local.0, &local.1), (&result, &params), "serve sync");
    for r in &reports {
        assert_eq!(r.rounds, c.rounds, "every round dispatches to every agent");
        // Acks ride the same ordered stream as DONE, so none are lost.
        assert_eq!(r.acks, r.uploads, "ack per upload");
        assert!(r.uploads > 0 && r.upload_bytes > 0, "{r:?}");
    }
    // Sync barrier: every slot uploads every round.
    assert_eq!(
        reports.iter().map(|r| r.uploads).sum::<usize>(),
        c.n_clients * c.rounds,
        "{reports:?}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn semi_async_loopback_matches_in_process_bitwise() {
    let dir = native_dir("semi");
    let mut c = cfg("feddd", &dir);
    c.round_mode = "semi_async".into();
    c.n_clients = 6;
    c.quorum = 0.7;
    c.staleness_beta = 0.5;
    c.rounds = 5;
    let local = in_process(&c);
    let (result, params, _) = loopback(&c, &[(0, Some(3)), (3, None)]);
    assert_bitwise_equal((&local.0, &local.1), (&result, &params), "serve semi_async");
    // The straggler machinery must actually engage for this to mean much.
    assert!(
        local.0.rounds.iter().any(|r| r.stragglers > 0),
        "quorum never left a straggler in flight"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn semi_async_churn_loopback_matches_in_process_bitwise() {
    // Mid-round churn exercises the churned close notes: the agent must
    // drop the pending residual without rebasing, exactly like the
    // in-process engine.
    let dir = native_dir("churn");
    let mut c = cfg("feddd", &dir);
    c.round_mode = "semi_async".into();
    c.n_clients = 6;
    c.quorum = 0.7;
    c.staleness_beta = 0.5;
    c.trace = "churn".into();
    c.churn_rate = 0.5;
    c.rounds = 6;
    let local = in_process(&c);
    let (result, params, _) = loopback(&c, &[(0, None)]);
    assert_bitwise_equal((&local.0, &local.1), (&result, &params), "serve churn");
    assert!(
        local.0.rounds.iter().any(|r| r.churned > 0),
        "churn trace never dropped an upload"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn oort_loopback_matches_in_process_bitwise() {
    // Oort's utility reads last_loss/participations, which the serve
    // coordinator mirrors at envelope receipt — a drifted mirror changes
    // the selection and fails this bitwise comparison.
    let dir = native_dir("oort");
    let c = cfg("oort", &dir);
    let local = in_process(&c);
    let (result, params, _) = loopback(&c, &[(0, Some(1)), (1, Some(3))]);
    assert_bitwise_equal((&local.0, &local.1), (&result, &params), "serve oort");
    let _ = std::fs::remove_dir_all(&dir);
}
