//! Wire-codec aggregation equivalence: `Aggregator::absorb_wire` must be
//! **bitwise identical** to the dense mask path (`add_client` with the
//! expanded elementwise mask) for every selection policy and mask shape
//! the four schemes produce (FedDD's partial masks, the baselines' full
//! masks), every shard partition / worker count, and hetero sub-model
//! corners — and the chosen encodings must strictly beat the dense
//! payload whenever dropout actually drops a unit.

use std::path::PathBuf;

use feddd::aggregation::{AggBackend, Aggregator};
use feddd::codec::{encode_upload, encode_upload_with, CodecMode, WireUpload};
use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::model::ModelSpec;
use feddd::runtime::write_native_manifest;
use feddd::selection::{select_mask, ChannelMask, Policy};
use feddd::tensor::Tensor;
use feddd::util::proptest::check;
use feddd::util::rng::Rng;

fn perturbed(p: &[Tensor], rng: &mut Rng, s: f32) -> Vec<Tensor> {
    p.iter()
        .map(|t| {
            let d: Vec<f32> = t.data().iter().map(|&x| x + rng.normal_f32(0.0, s)).collect();
            Tensor::new(t.shape().to_vec(), d)
        })
        .collect()
}

/// A client mask in one of the shapes the schemes produce: the baselines'
/// full mask or a FedDD policy selection at a random rate.
fn scheme_mask(spec: &ModelSpec, prev: &[Tensor], after: &[Tensor], rng: &mut Rng) -> ChannelMask {
    let policies = [
        Policy::Importance,
        Policy::Random,
        Policy::Max,
        Policy::Delta,
        Policy::Ordered,
    ];
    match rng.below(6) {
        0 => ChannelMask::full(spec), // fedavg / fedcs / oort upload shape
        i => {
            let d = rng.range_f64(0.05, 0.9);
            select_mask(policies[i - 1], spec, prev, after, None, d, rng)
        }
    }
}

#[test]
fn absorb_wire_matches_dense_add_client_bitwise() {
    // The core guarantee, for every layout the auto-pick can choose and
    // for the forced bitmap/COO modes: folding the encoded upload equals
    // expanding the mask and calling add_client, bit for bit.
    check("wire == dense fold", 20, |rng| {
        for name in ["mlp", "cnn1"] {
            let spec = ModelSpec::get(name, 0.5).unwrap();
            let prev = spec.init_params(rng);
            let n_clients = rng.int_range(1, 6);
            let clients: Vec<Vec<Tensor>> =
                (0..n_clients).map(|_| perturbed(&prev, rng, 0.05)).collect();
            let masks: Vec<ChannelMask> = clients
                .iter()
                .map(|c| scheme_mask(&spec, &prev, c, rng))
                .collect();
            let weights: Vec<f32> =
                (0..n_clients).map(|_| rng.range_f64(0.5, 200.0) as f32).collect();

            let dense = {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                for i in 0..n_clients {
                    let elems = masks[i].to_elementwise(&spec);
                    agg.add_client(&clients[i], &elems, weights[i], None).unwrap();
                }
                agg.finalize(&prev, None).unwrap()
            };
            for mode in [CodecMode::Auto, CodecMode::Bitmap, CodecMode::Coo] {
                let mut agg = Aggregator::new(&spec, AggBackend::Rust);
                for i in 0..n_clients {
                    let up = encode_upload_with(&masks[i], &clients[i], &spec, mode);
                    agg.absorb_wire(&up, weights[i]).unwrap();
                }
                if agg.clients_added() != n_clients {
                    return Err(format!("{name}: clients_added {}", agg.clients_added()));
                }
                let wire = agg.finalize(&prev, None).unwrap();
                for (i, (a, b)) in dense.iter().zip(&wire).enumerate() {
                    if a.data() != b.data() {
                        return Err(format!("{name} {mode:?}: tensor {i} differs"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn absorb_wire_matches_dense_in_hetero_corners() {
    // Hetero fleets embed sub-models at the leading corner of the global
    // tensors; absorb_wire's scatter must land on exactly the positions
    // add_client's embed covers — across all five sub-model widths.
    check("wire == dense fold (hetero)", 8, |rng| {
        let global = ModelSpec::get("het_a_1", 0.25).unwrap();
        let prev = global.init_params(rng);
        let mut dense_agg = Aggregator::new(&global, AggBackend::Rust);
        let mut wire_agg = Aggregator::new(&global, AggBackend::Rust);
        for i in 1..=5 {
            let sub = ModelSpec::get(&format!("het_a_{i}"), 0.25).unwrap();
            let params = sub.init_params(rng);
            let before = sub.init_params(rng);
            let mask = scheme_mask(&sub, &before, &params, rng);
            let m_n = rng.range_f64(1.0, 50.0) as f32;
            let elems = mask.to_elementwise(&sub);
            dense_agg.add_client(&params, &elems, m_n, None).unwrap();
            let up = encode_upload(&mask, &params, &sub);
            wire_agg.absorb_wire(&up, m_n).unwrap();
        }
        let a = dense_agg.finalize(&prev, None).unwrap();
        let b = wire_agg.finalize(&prev, None).unwrap();
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if x.data() != y.data() {
                return Err(format!("hetero tensor {i} differs"));
            }
        }
        Ok(())
    });
}

#[test]
fn sharded_wire_folds_are_partition_deterministic() {
    // Shard partials built with absorb_wire and merged pairwise must
    // equal the sequential dense aggregation bitwise, for every shard
    // length (the worker count never enters the partition).
    check("sharded wire folds", 10, |rng| {
        let spec = ModelSpec::get("mlp", 0.25).unwrap();
        let prev = spec.init_params(rng);
        let n_clients = rng.int_range(2, 9);
        let clients: Vec<Vec<Tensor>> =
            (0..n_clients).map(|_| perturbed(&prev, rng, 0.05)).collect();
        let uploads: Vec<WireUpload> = clients
            .iter()
            .map(|c| {
                let m = scheme_mask(&spec, &prev, c, rng);
                encode_upload(&m, c, &spec)
            })
            .collect();
        let weights: Vec<f32> = (0..n_clients).map(|_| (rng.below(100) + 1) as f32).collect();
        let sequential = {
            let mut agg = Aggregator::new(&spec, AggBackend::Rust);
            for i in 0..n_clients {
                agg.absorb_wire(&uploads[i], weights[i]).unwrap();
            }
            agg.finalize(&prev, None).unwrap()
        };
        for shard_len in 1..=n_clients {
            let mut shards = Vec::new();
            let mut i = 0;
            while i < n_clients {
                let end = (i + shard_len).min(n_clients);
                let mut shard = Aggregator::new(&spec, AggBackend::Rust);
                for j in i..end {
                    shard.absorb_wire(&uploads[j], weights[j]).unwrap();
                }
                shards.push(shard);
                i = end;
            }
            let merged = Aggregator::merge(shards).unwrap();
            if merged.clients_added() != n_clients {
                return Err("clients_added lost in merge".into());
            }
            let out = merged.finalize(&prev, None).unwrap();
            for (x, y) in out.iter().zip(&sequential) {
                if x.data() != y.data() {
                    return Err(format!("shard_len {shard_len} differs from sequential"));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Engine-level: the wire path drives full runs for all four schemes.
// ---------------------------------------------------------------------

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_wire_equiv_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(scheme: &str, workers: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.n_clients = 5;
    cfg.rounds = 3;
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 60;
    cfg.eval_every = 3;
    cfg.workers = workers;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

#[test]
fn engine_wire_path_is_worker_invariant_for_every_scheme() {
    // All four schemes now aggregate through absorb_wire; the bitwise
    // worker-count invariance must survive the codec rework, and the
    // new wire columns must be deterministic too.
    let dir = native_dir("schemes");
    for scheme in ["feddd", "fedavg", "fedcs", "oort"] {
        let run_once = |workers: usize| {
            let mut run = FedRun::new(cfg(scheme, workers, &dir)).unwrap();
            let res = run.run().unwrap();
            (res, run.global_params.clone())
        };
        let (res1, par1) = run_once(1);
        let (res4, par4) = run_once(4);
        for (a, b) in res1.rounds.iter().zip(&res4.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{scheme}");
            assert_eq!(a.uploaded_bytes, b.uploaded_bytes, "{scheme}");
            assert_eq!(a.wire_bytes, b.wire_bytes, "{scheme}");
            assert_eq!(a.encodings, b.encodings, "{scheme}");
        }
        for (i, (x, y)) in par1.iter().zip(&par4).enumerate() {
            assert_eq!(x.data(), y.data(), "{scheme}: global tensor {i}");
        }
        assert_eq!(res1.total_wire_bytes(), res4.total_wire_bytes(), "{scheme}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn engine_wire_bytes_beat_dense_under_dropout() {
    // Acceptance: once FedDD allocates d > 0 (round 2 on), the realized
    // wire bytes are strictly below the dense full-model volume, the
    // uploads stop being all-dense, and wire_bytes stays within the
    // documented bound of payload + framing.
    let dir = native_dir("savings");
    let mut run = FedRun::new(cfg("feddd", 2, &dir)).unwrap();
    let full_model_bytes: usize = run.clients.iter().map(|c| c.u_bytes()).sum();
    let res = run.run().unwrap();
    let r1 = &res.rounds[0];
    // round 1 uploads everything: all-dense encodings, payload == model
    assert_eq!(r1.uploaded_bytes, full_model_bytes);
    assert_eq!(r1.encodings.bitmap + r1.encodings.coo, 0, "round 1 not dense");
    assert!(r1.wire_bytes > r1.uploaded_bytes, "framing bytes missing");
    for r in res.rounds.iter().skip(1) {
        assert!(
            r.wire_bytes < full_model_bytes,
            "round {}: wire {} !< dense {}",
            r.round,
            r.wire_bytes,
            full_model_bytes
        );
        assert!(
            r.encodings.bitmap + r.encodings.coo > 0,
            "round {}: dropout produced only dense layers",
            r.round
        );
        assert!(r.wire_bytes >= r.uploaded_bytes, "round {}: wire below payload", r.round);
    }
    // fedavg for the same fleet is all-dense, every round
    let mut run = FedRun::new(cfg("fedavg", 2, &dir)).unwrap();
    let res = run.run().unwrap();
    for r in &res.rounds {
        assert_eq!(r.encodings.bitmap + r.encodings.coo, 0, "fedavg round {}", r.round);
        assert_eq!(r.uploaded_bytes, full_model_bytes);
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn forced_codec_modes_do_not_change_the_math() {
    // --codec bitmap/coo change bytes on the wire, never the model:
    // losses and global params must equal the auto run bitwise; wire
    // bytes must be >= auto's (auto picks the smallest layout).
    let dir = native_dir("modes");
    let run_with = |codec: &str| {
        let mut c = cfg("feddd", 2, &dir);
        c.codec = codec.into();
        let mut run = FedRun::new(c).unwrap();
        let res = run.run().unwrap();
        (res, run.global_params.clone())
    };
    let (auto_res, auto_par) = run_with("auto");
    for mode in ["bitmap", "coo"] {
        let (res, par) = run_with(mode);
        for (a, b) in auto_res.rounds.iter().zip(&res.rounds) {
            assert_eq!(a.train_loss.to_bits(), b.train_loss.to_bits(), "{mode}");
            assert_eq!(a.uploaded_bytes, b.uploaded_bytes, "{mode}");
            assert!(b.wire_bytes >= a.wire_bytes, "{mode} beat auto-pick");
        }
        for (x, y) in auto_par.iter().zip(&par) {
            assert_eq!(x.data(), y.data(), "{mode}: global params differ");
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}
