//! Determinism & regression battery for the persistent worker pool.
//!
//! PR 1's headline guarantee — a round is bitwise identical for every
//! `workers` value — was authored against a spawn-per-call pool. This
//! battery re-proves it against the persistent pool and its per-worker
//! scratch arenas, where the new failure mode is *stale scratch*: a
//! buffer that survives across micro-batches and rounds could leak a
//! previous client's bytes into the current job. Three angles:
//!
//! * **sweep** — `workers ∈ {1, 2, 3, 8}` × `round_mode` × codec layout,
//!   asserting the full `RunResult` (losses, durations, wire bytes,
//!   client-state accounting, straggler/staleness columns, evals) and
//!   the final global parameters are bitwise identical to `workers = 1`;
//! * **scratch poisoning** — `FedRun::poison_worker_scratch` fills every
//!   arena (coordinator materialization/batch buffers, the native
//!   executor's buffer pool, on every worker thread) with sentinels
//!   between rounds; outputs must not move by a bit, proving every
//!   consumer fully overwrites what it reads;
//! * **spawn accounting** — a run's OS thread spawns equal its pool size
//!   and stepping rounds spawns nothing, i.e. O(workers), never
//!   O(micro-batches) (`util::threadpool::total_threads_spawned`).
//!
//! Runs against a native-exec manifest (pure-Rust FC executor) so the
//! battery is green on any host, no libxla or prebuilt HLO required.

use std::path::PathBuf;
use std::sync::Mutex;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::metrics::RunResult;
use feddd::runtime::write_native_manifest;
use feddd::tensor::Tensor;
use feddd::util::threadpool::total_threads_spawned;

/// Every test in this binary serializes on one lock: the spawn-count
/// assertions read the process-wide spawn counter, which concurrently
/// constructed pools (each test builds `FedRun`s) would pollute.
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

fn native_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("feddd_pool_det_{}_{tag}", std::process::id()));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(dir: &PathBuf, workers: usize, round_mode: &str, codec: &str) -> ExpConfig {
    cfg_scheme(dir, "feddd", workers, round_mode, codec)
}

fn cfg_scheme(
    dir: &PathBuf,
    scheme: &str,
    workers: usize,
    round_mode: &str,
    codec: &str,
) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.n_clients = 6;
    cfg.rounds = 4;
    cfg.h = 3; // rounds 1 and 3 broadcast; 2 and 4 leave residuals
    cfg.local_steps = 2;
    cfg.test_n = 128;
    cfg.train_per_client = 50;
    cfg.eval_every = 4;
    cfg.workers = workers;
    cfg.round_mode = round_mode.into();
    cfg.codec = codec.into();
    if round_mode == "semi_async" {
        // A real quorum: every round leaves stragglers whose uploads fold
        // later with a staleness discount — worker-count invariance must
        // hold through the buffered path too.
        cfg.quorum = 0.7;
        cfg.staleness_beta = 0.5;
    }
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn run_once(cfg: ExpConfig) -> (RunResult, Vec<Tensor>) {
    let mut run = FedRun::new(cfg).unwrap();
    let result = run.run().unwrap();
    (result, run.global_params.clone())
}

/// Full bitwise comparison of two runs: every round column that derives
/// from client math or timing, every eval, every global parameter bit.
fn assert_bitwise(a: &(RunResult, Vec<Tensor>), b: &(RunResult, Vec<Tensor>), ctx: &str) {
    assert_bitwise_rows(a, b, ctx, true);
}

/// [`assert_bitwise`] with the `full_broadcast` column optionally
/// excluded. The `fed_dropout` rate-0 ≡ `fedavg` equivalence is
/// byte-for-byte in every quantity that derives from client math, bytes
/// on the wire or timing — but `fedavg` (stateless) stamps every round
/// as a full broadcast while `fed_dropout` (stateful) rides the
/// `h`-schedule, so that one bookkeeping flag legitimately differs.
fn assert_bitwise_rows(
    a: &(RunResult, Vec<Tensor>),
    b: &(RunResult, Vec<Tensor>),
    ctx: &str,
    compare_broadcast: bool,
) {
    assert_eq!(a.0.rounds.len(), b.0.rounds.len(), "{ctx}: round count");
    for (x, y) in a.0.rounds.iter().zip(&b.0.rounds) {
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{ctx} r{r} loss");
        assert_eq!(x.duration.to_bits(), y.duration.to_bits(), "{ctx} r{r} duration");
        assert_eq!(x.v_time.to_bits(), y.v_time.to_bits(), "{ctx} r{r} v_time");
        assert_eq!(x.uploaded_bytes, y.uploaded_bytes, "{ctx} r{r} uploaded");
        assert_eq!(x.wire_bytes, y.wire_bytes, "{ctx} r{r} wire");
        assert_eq!(x.client_state_bytes, y.client_state_bytes, "{ctx} r{r} state");
        assert_eq!(x.participants, y.participants, "{ctx} r{r} participants");
        assert_eq!(x.stragglers, y.stragglers, "{ctx} r{r} stragglers");
        assert_eq!(
            x.mean_staleness.to_bits(),
            y.mean_staleness.to_bits(),
            "{ctx} r{r} staleness"
        );
        if compare_broadcast {
            assert_eq!(x.full_broadcast, y.full_broadcast, "{ctx} r{r} broadcast");
        }
    }
    assert_eq!(a.0.evals.len(), b.0.evals.len(), "{ctx}: eval count");
    for (x, y) in a.0.evals.iter().zip(&b.0.evals) {
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{ctx} eval acc");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{ctx} eval loss");
    }
    for (i, (x, y)) in a.1.iter().zip(&b.1).enumerate() {
        assert_eq!(x.data(), y.data(), "{ctx}: global tensor {i}");
    }
}

#[test]
fn pooled_engine_matches_workers_1_across_modes_and_codecs() {
    let _g = serial();
    let dir = native_dir("sweep");
    for round_mode in ["sync", "semi_async"] {
        for codec in ["auto", "bitmap", "coo"] {
            let reference = run_once(cfg(&dir, 1, round_mode, codec));
            for workers in [2usize, 3, 8] {
                let out = run_once(cfg(&dir, workers, round_mode, codec));
                assert_bitwise(
                    &reference,
                    &out,
                    &format!("{round_mode}/{codec}/workers={workers}"),
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn scratch_poisoning_between_rounds_never_changes_outputs() {
    // The stale-scratch case: sentinel-fill every per-worker arena (the
    // materialization target, the pre-training copy, the batch buffers,
    // the native executor's buffer pool — on the caller thread and every
    // pool worker) before the run starts and again between every pair of
    // rounds. A single byte read before being rewritten surfaces as a
    // NaN loss or diverged global parameters.
    let _g = serial();
    let dir = native_dir("poison");
    for workers in [1usize, 3] {
        for round_mode in ["sync", "semi_async"] {
            let base = cfg(&dir, workers, round_mode, "auto");
            let mut clean = FedRun::new(base.clone()).unwrap();
            let mut poisoned = FedRun::new(base).unwrap();
            poisoned.poison_worker_scratch();
            let ctx = format!("w{workers}/{round_mode}");
            for r in 1..=4 {
                let a = clean.step_round().unwrap();
                let b = poisoned.step_round().unwrap();
                assert_eq!(
                    a.mean_loss.to_bits(),
                    b.mean_loss.to_bits(),
                    "{ctx} r{r}: loss drifted under poisoning"
                );
                assert_eq!(a.duration.to_bits(), b.duration.to_bits(), "{ctx} r{r} duration");
                assert_eq!(a.uploaded_bytes, b.uploaded_bytes, "{ctx} r{r} uploaded");
                assert_eq!(a.wire_bytes, b.wire_bytes, "{ctx} r{r} wire");
                assert_eq!(a.client_state_bytes, b.client_state_bytes, "{ctx} r{r} state");
                poisoned.poison_worker_scratch();
            }
            for (i, (x, y)) in clean
                .global_params
                .iter()
                .zip(&poisoned.global_params)
                .enumerate()
            {
                assert_eq!(x.data(), y.data(), "{ctx}: global tensor {i} drifted");
                assert!(x.data().iter().all(|v| v.is_finite()), "{ctx}: tensor {i} non-finite");
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn thread_spawns_are_o_workers_not_o_micro_batches() {
    let _g = serial();
    let dir = native_dir("spawns");
    for workers in [1usize, 2, 3, 8] {
        let mut c = cfg(&dir, workers, "sync", "auto");
        // 40 clients at micro = max(4·workers, 32) gives ≥ 2 micro-batch
        // dispatches per round × 3 rounds — each of which the old
        // spawn-per-call pool paid min(workers, n) fresh OS threads for.
        c.n_clients = 40;
        c.rounds = 3;
        c.train_per_client = 4;
        c.local_steps = 1;
        c.eval_every = 3;
        let before = total_threads_spawned();
        let mut run = FedRun::new(c).unwrap();
        let after_new = total_threads_spawned();
        let expected = if workers > 1 { workers } else { 0 };
        assert_eq!(
            after_new - before,
            expected,
            "pool construction must spawn exactly the pool (w={workers})"
        );
        assert_eq!(run.pool_threads(), expected);
        run.run().unwrap();
        assert_eq!(
            total_threads_spawned(),
            after_new,
            "stepping rounds must spawn zero OS threads (w={workers})"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dropout_family_schemes_match_workers_1_across_modes() {
    // The dropout-family baselines introduce a new dataflow — server-
    // chosen dispatch-time masks (random for `fed_dropout`, activation-
    // scored for `afd`) — that must inherit the worker-count invariance
    // wholesale: mask RNG is a pure function of (seed, round, client),
    // AFD's EMA observation runs on the single-threaded coordinator, and
    // neither perturbs the engine's split-order RNG streams.
    let _g = serial();
    let dir = native_dir("dropzoo");
    for scheme in ["fed_dropout", "afd"] {
        for round_mode in ["sync", "semi_async"] {
            let reference = run_once(cfg_scheme(&dir, scheme, 1, round_mode, "auto"));
            for workers in [2usize, 4] {
                let out = run_once(cfg_scheme(&dir, scheme, workers, round_mode, "auto"));
                assert_bitwise(
                    &reference,
                    &out,
                    &format!("{scheme}/{round_mode}/workers={workers}"),
                );
            }
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fed_dropout_rate_zero_reproduces_fedavg_bytewise() {
    // `fd_rate = 0` keeps every unit: the random mask is full, its
    // residual complement empty, and the dispatch-mask RNG draws from a
    // pure hash rather than any engine stream — so the run must collapse
    // onto `fedavg` byte-for-byte (losses, wire bytes, timing, evals,
    // final parameters). Only the `full_broadcast` bookkeeping flag
    // differs, and the test pins that too: if the schedules ever stopped
    // differing, the excluded column would be dead weight.
    let _g = serial();
    let dir = native_dir("rate0");
    for round_mode in ["sync", "semi_async"] {
        let mut fd = cfg_scheme(&dir, "fed_dropout", 2, round_mode, "auto");
        fd.fd_rate = 0.0;
        let a = run_once(fd);
        let b = run_once(cfg_scheme(&dir, "fedavg", 2, round_mode, "auto"));
        assert_bitwise_rows(&a, &b, &format!("rate0/{round_mode}"), false);
        assert!(
            b.0.rounds.iter().all(|r| r.full_broadcast),
            "{round_mode}: fedavg must broadcast every round"
        );
        assert!(
            a.0.rounds.iter().any(|r| !r.full_broadcast),
            "{round_mode}: fed_dropout must ride the h-schedule (h = 3 leaves \
             rounds 2 and 4 partial) or the excluded column proves nothing"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}
