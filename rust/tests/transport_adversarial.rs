//! Adversarial serve-mode transport tests: garbage, truncated and
//! oversized frames, half-written stalls and mid-frame disconnects must
//! each be rejected (or timed out) without killing the server, hanging a
//! round, or poisoning the run for well-behaved agents.
//!
//! Frame-layer rejection (oversized prefixes before allocation,
//! truncation, trailing bytes) is unit-tested inside
//! `feddd::transport::frame`; these tests attack a *live* server over
//! real 127.0.0.1 sockets.

use std::io::Write;
use std::net::TcpStream;
use std::path::PathBuf;
use std::thread;
use std::time::Duration;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::write_native_manifest;
use feddd::transport::frame::{
    read_frame, write_frame, Hello, FT_CONFIG, FT_HELLO, FT_UPLOAD,
};
use feddd::transport::{run_agent, AgentOpts, BoundServer, ServeOpts};

fn native_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "feddd_transport_adv_{}_{tag}",
        std::process::id()
    ));
    write_native_manifest(&dir, &[("mlp", 1.0)], 16, 64).unwrap();
    dir
}

fn cfg(dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = "feddd".into();
    cfg.n_clients = 2;
    cfg.rounds = 2;
    cfg.local_steps = 2;
    cfg.test_n = 64;
    cfg.train_per_client = 60;
    cfg.eval_every = 2;
    cfg.workers = 1;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// Short timeouts so hostile stalls resolve in test time.
fn serve_opts(cfg: &ExpConfig) -> ServeOpts {
    let mut opts = ServeOpts::from_config(cfg);
    opts.listen = "127.0.0.1:0".into();
    opts.accept_timeout = Duration::from_secs(30);
    opts.hello_timeout = Duration::from_millis(400);
    opts.read_timeout = Duration::from_millis(400);
    opts.round_timeout = Duration::from_secs(10);
    opts
}

#[test]
fn hostile_connections_do_not_block_a_real_run() {
    // Five attacks hit the accept loop while one honest agent serves the
    // whole fleet; the run must complete with correct results anyway.
    let dir = native_dir("accept");
    let c = cfg(&dir);
    let opts = serve_opts(&c);
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();

    let attackers: Vec<_> = (0..5)
        .map(|kind| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut s = TcpStream::connect(&addr).unwrap();
                match kind {
                    // Plain garbage that is not even a frame.
                    0 => {
                        let _ = s.write_all(b"GET / HTTP/1.1\r\n\r\n");
                    }
                    // A frame whose length prefix claims u32::MAX bytes.
                    1 => {
                        let mut head = vec![FT_HELLO];
                        head.extend_from_slice(&u32::MAX.to_le_bytes());
                        let _ = s.write_all(&head);
                    }
                    // A truncated HELLO: header promises more than sent.
                    2 => {
                        let mut buf = Vec::new();
                        write_frame(&mut buf, FT_HELLO, &[0u8; 14]).unwrap();
                        let _ = s.write_all(&buf[..buf.len() - 6]);
                    }
                    // A mid-frame disconnect: half a header, then gone.
                    3 => {
                        let _ = s.write_all(&[FT_HELLO, 9]);
                        drop(s);
                        return;
                    }
                    // A silent stall: connect and send nothing at all.
                    _ => {}
                }
                // Keep the socket open past the server's hello timeout so
                // rejection, not our disconnect, is what frees the slot.
                thread::sleep(Duration::from_millis(900));
            })
        })
        .collect();
    // Give the attackers a head start so they really do land first.
    thread::sleep(Duration::from_millis(50));
    let honest = {
        let addr = addr.clone();
        thread::spawn(move || {
            run_agent(&AgentOpts {
                connect: addr,
                slot_start: 0,
                slot_count: None,
                overrides: Vec::new(),
            })
            .unwrap()
        })
    };

    let coordinator = bound.accept_agents(&opts, &c).unwrap();
    let mut run = FedRun::with_transport(c.clone(), Box::new(coordinator)).unwrap();
    let result = run.run().unwrap();
    run.shutdown_transport().unwrap();
    let report = honest.join().unwrap();
    for a in attackers {
        a.join().unwrap();
    }
    assert_eq!(result.rounds.len(), c.rounds);
    assert!(result.rounds.iter().all(|r| r.train_loss.is_finite()));
    assert_eq!(report.rounds, c.rounds);
    assert_eq!(report.uploads, c.n_clients * c.rounds);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Handshake as an agent would, without being one: HELLO out, CONFIG in.
fn fake_handshake(addr: &str) -> TcpStream {
    let mut s = TcpStream::connect(addr).unwrap();
    let hello = Hello { slot_start: 0, slot_count: 0 };
    write_frame(&mut s, FT_HELLO, &hello.encode()).unwrap();
    let (ty, _) = read_frame(&mut s, 1 << 20).unwrap();
    assert_eq!(ty, FT_CONFIG);
    s
}

#[test]
fn mid_round_disconnect_fails_the_round_not_the_process() {
    // A correctly handshaken "agent" that dies mid-upload: the reader
    // reports the close and the round returns an error instead of
    // hanging on the barrier or panicking.
    let dir = native_dir("disconnect");
    let c = cfg(&dir);
    let opts = serve_opts(&c);
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();
    let fake = thread::spawn(move || {
        let mut s = fake_handshake(&addr);
        // Swallow the round-1 dispatch, answer with half an upload
        // frame, then vanish.
        let (_, _) = read_frame(&mut s, 1 << 30).unwrap();
        let _ = s.write_all(&[FT_UPLOAD, 0xff, 0xff, 0x00, 0x00, 1, 2, 3]);
        drop(s);
    });
    let coordinator = bound.accept_agents(&opts, &c).unwrap();
    let mut run = FedRun::with_transport(c.clone(), Box::new(coordinator)).unwrap();
    let err = run.step_round().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("lost mid-round"), "unexpected error: {msg}");
    run.shutdown_transport().unwrap();
    fake.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn stalled_half_written_upload_times_out_the_round() {
    // A handshaken "agent" that writes half an upload frame and then
    // just stops: the per-read timeout must flag the stall (or, at
    // worst, the round timeout must fire) — the server never hangs.
    let dir = native_dir("stall");
    let c = cfg(&dir);
    let opts = serve_opts(&c);
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();
    let fake = thread::spawn(move || {
        let mut s = fake_handshake(&addr);
        let (_, _) = read_frame(&mut s, 1 << 30).unwrap();
        // Three header bytes of an upload, then silence — but the
        // socket stays open well past the server's read timeout.
        let _ = s.write_all(&[FT_UPLOAD, 0x10, 0x00]);
        thread::sleep(Duration::from_secs(4));
        drop(s);
    });
    let coordinator = bound.accept_agents(&opts, &c).unwrap();
    let mut run = FedRun::with_transport(c.clone(), Box::new(coordinator)).unwrap();
    let err = run.step_round().unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("mid-frame") || msg.contains("gave up waiting"),
        "unexpected error: {msg}"
    );
    run.shutdown_transport().unwrap();
    fake.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn overlapping_slot_claims_are_rejected() {
    // Two claimants for slot 0: the first one in wins, the duplicate is
    // dropped, and a correct agent for the remaining slot completes the
    // fleet. (Which attacker-vs-agent order happens first is racy, so
    // the duplicate here arrives strictly after the honest agent.)
    let dir = native_dir("overlap");
    let c = cfg(&dir);
    let opts = serve_opts(&c);
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();

    let honest_first = {
        let addr = addr.clone();
        thread::spawn(move || {
            run_agent(&AgentOpts {
                connect: addr,
                slot_start: 0,
                slot_count: Some(1),
                overrides: Vec::new(),
            })
            .unwrap()
        })
    };
    // Wait until slot 0's owner is surely handshaken, then double-claim
    // it; the server must reject us and keep waiting for slot 1.
    thread::sleep(Duration::from_millis(300));
    let dup = {
        let addr = addr.clone();
        thread::spawn(move || {
            let mut s = TcpStream::connect(&addr).unwrap();
            let hello = Hello { slot_start: 0, slot_count: 1 };
            write_frame(&mut s, FT_HELLO, &hello.encode()).unwrap();
            // Rejected: the connection just closes with no CONFIG.
            assert!(read_frame(&mut s, 1 << 20).is_err());
        })
    };
    let honest_second = {
        let addr = addr.clone();
        thread::spawn(move || {
            // Arrive after the duplicate claim.
            thread::sleep(Duration::from_millis(600));
            run_agent(&AgentOpts {
                connect: addr,
                slot_start: 1,
                slot_count: None,
                overrides: Vec::new(),
            })
            .unwrap()
        })
    };

    let coordinator = bound.accept_agents(&opts, &c).unwrap();
    let mut run = FedRun::with_transport(c.clone(), Box::new(coordinator)).unwrap();
    let result = run.run().unwrap();
    run.shutdown_transport().unwrap();
    assert_eq!(result.rounds.len(), c.rounds);
    assert_eq!(honest_first.join().unwrap().uploads, c.rounds);
    assert_eq!(honest_second.join().unwrap().uploads, c.rounds);
    dup.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
