//! End-to-end training tests over the full three-layer stack: the rust
//! coordinator drives the AOT XLA executables (which embed the Pallas
//! kernels) for several rounds and must actually *learn* — plus scheme
//! parity checks on budget accounting and mask semantics.

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::default_artifacts_dir;

fn have_artifacts() -> bool {
    default_artifacts_dir().join("manifest.json").exists()
}

fn smoke(scheme: &str) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.n_clients = 5;
    cfg.rounds = 10;
    cfg.local_steps = 4;
    cfg.lr = 0.08;
    cfg.test_n = 128;
    cfg.train_per_client = 100;
    cfg.eval_every = 10;
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();
    cfg
}

#[test]
fn feddd_learns_and_respects_budget() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut run = FedRun::new(smoke("feddd")).unwrap();
    let budget = run.budget_bytes();
    let result = run.run().unwrap();
    // learning signal
    let first = result.rounds.first().unwrap().train_loss;
    let last = result.rounds.last().unwrap().train_loss;
    assert!(last < first * 0.8, "loss {first} -> {last}");
    assert!(result.final_accuracy().unwrap() > 0.5);
    // rounds after the first obey the byte budget (first is full upload)
    for r in result.rounds.iter().skip(1) {
        assert!(
            r.uploaded_bytes as f64 <= budget as f64 * 1.02,
            "round {} uploaded {} > budget {}",
            r.round,
            r.uploaded_bytes,
            budget
        );
        assert_eq!(r.participants, 5); // FedDD drops parameters, not clients
    }
    // virtual clock monotone
    let mut prev = 0.0;
    for r in &result.rounds {
        assert!(r.v_time > prev);
        prev = r.v_time;
    }
}

#[test]
fn fedavg_uploads_full_models() {
    if !have_artifacts() {
        return;
    }
    let mut run = FedRun::new(smoke("fedavg")).unwrap();
    let full: usize = run.clients.iter().map(|c| c.u_bytes()).sum();
    let result = run.run().unwrap();
    for r in &result.rounds {
        assert_eq!(r.uploaded_bytes, full);
    }
}

#[test]
fn client_selection_schemes_drop_clients_under_budget() {
    if !have_artifacts() {
        return;
    }
    for scheme in ["fedcs", "oort"] {
        let mut cfg = smoke(scheme);
        cfg.a_server = 0.4; // tight budget -> at most 2 of 5 clients
        let mut run = FedRun::new(cfg).unwrap();
        let result = run.run().unwrap();
        for r in &result.rounds {
            assert!(
                r.participants <= 2,
                "{scheme} round {} had {} participants",
                r.round,
                r.participants
            );
        }
    }
}

#[test]
fn sparse_rounds_upload_less_than_broadcast_rounds_download() {
    if !have_artifacts() {
        return;
    }
    // h=2: odd rounds sparse download, even rounds full broadcast.
    let mut cfg = smoke("feddd");
    cfg.h = 2;
    cfg.rounds = 4;
    let mut run = FedRun::new(cfg).unwrap();
    let result = run.run().unwrap();
    assert!(result.rounds[1].full_broadcast);
    assert!(!result.rounds[2].full_broadcast);
}

#[test]
fn xla_agg_backend_end_to_end_matches_rust_backend() {
    if !have_artifacts() {
        return;
    }
    let run_with = |backend: &str| -> Vec<f64> {
        let mut cfg = smoke("feddd");
        cfg.agg_backend = backend.into();
        cfg.rounds = 2;
        let mut run = FedRun::new(cfg).unwrap();
        let res = run.run().unwrap();
        res.rounds.iter().map(|r| r.train_loss).collect()
    };
    let a = run_with("rust");
    let b = run_with("xla");
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-3, "{a:?} vs {b:?}");
    }
}

#[test]
fn hetero_end_to_end_smoke() {
    if !have_artifacts() {
        return;
    }
    let mut cfg = smoke("feddd");
    cfg.model = "het_a".into();
    cfg.dataset = "cifar10".into();
    cfg.width_pct = 25;
    cfg.rounds = 2;
    cfg.lr = 0.02;
    let mut run = FedRun::new(cfg).unwrap();
    let result = run.run().unwrap();
    assert_eq!(result.rounds.len(), 2);
    assert!(result.rounds.iter().all(|r| r.train_loss.is_finite()));
    // five different sub-model sizes in the fleet
    let mut sizes: Vec<usize> = run.clients.iter().map(|c| c.u_bytes()).collect();
    sizes.dedup();
    assert!(sizes.len() >= 2);
}

#[test]
fn determinism_same_seed_same_result() {
    if !have_artifacts() {
        return;
    }
    let run = |seed: u64| -> f64 {
        let mut cfg = smoke("feddd");
        cfg.rounds = 2;
        cfg.seed = seed;
        FedRun::new(cfg).unwrap().run().unwrap().rounds[1].train_loss
    };
    assert_eq!(run(5).to_bits(), run(5).to_bits());
    assert_ne!(run(5).to_bits(), run(6).to_bits());
}
