//! Offline stub of the `xla` crate (PJRT CPU bindings) — the exact API
//! surface `rust/src/runtime/pjrt.rs` consumes, with every entry point
//! that would require a native libxla returning a clean [`Error`].
//!
//! Why a stub: this build environment ships no XLA shared library, and the
//! HLO artifacts are produced out-of-band (`python/compile/aot.py`). The
//! feddd runtime selects its execution backend from the artifact manifest;
//! manifests with `"exec": "native"` never touch this crate, while PJRT
//! manifests fail fast at `PjRtClient::cpu()` with an actionable message.
//! Literal marshalling is implemented for real (it is pure byte shuffling)
//! so host-side code paths stay exercised by tests.

use std::fmt;

const STUB_MSG: &str = "PJRT unavailable: the vendored `xla` crate is an offline stub \
     (no libxla). Use a native-exec artifact manifest or link the real xla crate.";

/// Stub error type (message only).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn stub() -> Error {
        Error { msg: STUB_MSG.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types the feddd runtime marshals.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    pub fn byte_size(self) -> usize {
        4
    }
}

/// Sealed-ish marker for host element types.
pub trait NativeType: Copy {
    const TY: ElementType;
    fn from_le(raw: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn from_le(raw: [u8; 4]) -> Self {
        f32::from_le_bytes(raw)
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn from_le(raw: [u8; 4]) -> Self {
        i32::from_le_bytes(raw)
    }
}

/// A host literal: element type + shape + raw little-endian bytes.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let want = shape.iter().product::<usize>() * ty.byte_size();
        if data.len() != want {
            return Err(Error {
                msg: format!("literal size mismatch: {} bytes for shape {shape:?}", data.len()),
            });
        }
        Ok(Literal { ty, shape: shape.to_vec(), bytes: data.to_vec() })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Reinterpret the raw bytes as a typed vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if T::TY != self.ty {
            return Err(Error { msg: format!("dtype mismatch: literal is {:?}", self.ty) });
        }
        let mut out = Vec::with_capacity(self.bytes.len() / 4);
        for chunk in self.bytes.chunks_exact(4) {
            let mut raw = [0u8; 4];
            raw.copy_from_slice(chunk);
            out.push(T::from_le(raw));
        }
        Ok(out)
    }

    /// Decompose a tuple literal (executables here return tuples). The
    /// stub never produces tuples, so this is always an error.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::stub())
    }
}

/// Parsed HLO module handle — loading requires libxla, so the stub errors.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub())
    }
}

/// An XLA computation built from a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub())
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub())
    }
}

/// PJRT client — construction fails in the stub, so callers learn at
/// `Runtime::new` time that artifact execution needs a real libxla.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn client_is_stubbed() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
