//! Minimal offline shim of the `anyhow` API surface this repository uses:
//! [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! The build environment has no crates.io access, so the real crate is
//! replaced by this message-carrying error type. Any `std::error::Error`
//! converts into [`Error`] via `?` exactly like upstream anyhow; context
//! chaining and backtraces are intentionally out of scope.

use std::fmt;

/// A message-carrying error type, convertible from any std error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (the `anyhow!` entry point).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which keeps
// this blanket conversion coherent (same trick as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `std::result::Result` with [`Error`] default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    fn io_fail() -> crate::Result<()> {
        std::fs::read("/definitely/not/a/path/xyz")?;
        Ok(())
    }

    fn checked(x: i32) -> crate::Result<i32> {
        crate::ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            crate::bail!("x too large: {}", x);
        }
        Ok(x)
    }

    #[test]
    fn conversions_and_macros() {
        assert!(io_fail().is_err());
        assert_eq!(checked(5).unwrap(), 5);
        assert!(checked(-1).unwrap_err().to_string().contains("positive"));
        assert!(checked(200).unwrap_err().to_string().contains("too large"));
        let e = crate::anyhow!("plain {}", 7);
        assert_eq!(format!("{e}"), "plain 7");
        assert_eq!(format!("{e:?}"), "plain 7");
    }
}
