//! Minimal offline shim of the `log` facade: the subset this repository
//! uses — [`Level`], [`LevelFilter`], [`Metadata`], [`Record`], the
//! [`Log`] trait, `set_logger` / `set_max_level`, and the leveled macros.
//! API-compatible with the real crate for these items so the code builds
//! unchanged when the real facade is available.

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Verbosity level of a log record (ordered: Error < Warn < … < Trace).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Level {
    Error = 1,
    Warn,
    Info,
    Debug,
    Trace,
}

impl fmt::Display for Level {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        // honor width/alignment flags ({:5} etc.)
        f.pad(s)
    }
}

/// Maximum-verbosity filter installed with [`set_max_level`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LevelFilter {
    Off = 0,
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

/// Metadata about a record: its level and target module path.
#[derive(Clone, Debug)]
pub struct Metadata<'a> {
    level: Level,
    target: &'a str,
}

impl<'a> Metadata<'a> {
    pub fn level(&self) -> Level {
        self.level
    }

    pub fn target(&self) -> &'a str {
        self.target
    }
}

/// One log record: metadata plus preformatted arguments.
#[derive(Clone, Debug)]
pub struct Record<'a> {
    metadata: Metadata<'a>,
    args: fmt::Arguments<'a>,
}

impl<'a> Record<'a> {
    pub fn metadata(&self) -> &Metadata<'a> {
        &self.metadata
    }

    pub fn level(&self) -> Level {
        self.metadata.level
    }

    pub fn target(&self) -> &'a str {
        self.metadata.target
    }

    pub fn args(&self) -> &fmt::Arguments<'a> {
        &self.args
    }
}

/// A logging backend.
pub trait Log: Send + Sync {
    fn enabled(&self, metadata: &Metadata) -> bool;
    fn log(&self, record: &Record);
    fn flush(&self);
}

static LOGGER: OnceLock<&'static dyn Log> = OnceLock::new();
static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Off as usize);

/// Error returned when a logger is already installed.
#[derive(Debug)]
pub struct SetLoggerError(());

impl fmt::Display for SetLoggerError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("a logger is already installed")
    }
}

impl std::error::Error for SetLoggerError {}

/// Install the global logger (first caller wins).
pub fn set_logger(logger: &'static dyn Log) -> Result<(), SetLoggerError> {
    LOGGER.set(logger).map_err(|_| SetLoggerError(()))
}

/// Set the global maximum log level.
pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

/// The current global maximum log level.
pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        5 => LevelFilter::Trace,
        _ => LevelFilter::Off,
    }
}

/// Macro plumbing — public because macros expand in downstream crates.
#[doc(hidden)]
pub fn __private_log(level: Level, target: &str, args: fmt::Arguments) {
    if (level as usize) > MAX_LEVEL.load(Ordering::Relaxed) {
        return;
    }
    if let Some(logger) = LOGGER.get() {
        let record = Record { metadata: Metadata { level, target }, args };
        if logger.enabled(&record.metadata) {
            logger.log(&record);
        }
    }
}

#[macro_export]
macro_rules! log {
    ($lvl:expr, $($arg:tt)+) => {
        $crate::__private_log($lvl, module_path!(), format_args!($($arg)+))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Error, $($arg)+) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Warn, $($arg)+) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Info, $($arg)+) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Debug, $($arg)+) };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)+) => { $crate::log!($crate::Level::Trace, $($arg)+) };
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    static HITS: AtomicUsize = AtomicUsize::new(0);

    struct Counter;

    impl Log for Counter {
        fn enabled(&self, metadata: &Metadata) -> bool {
            metadata.level() <= Level::Info
        }
        fn log(&self, record: &Record) {
            if self.enabled(record.metadata()) {
                HITS.fetch_add(1, Ordering::Relaxed);
            }
        }
        fn flush(&self) {}
    }

    #[test]
    fn levels_filter_and_count() {
        let _ = set_logger(&Counter);
        set_max_level(LevelFilter::Info);
        crate::info!("hello {}", 1);
        crate::debug!("filtered out");
        assert!(HITS.load(Ordering::Relaxed) >= 1);
        assert!(Level::Error < Level::Trace);
        assert_eq!(format!("{:5}", Level::Warn), "WARN ");
    }
}
