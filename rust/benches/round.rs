//! End-to-end round bench: one full synchronous FedDD round (train +
//! select + shard-aggregate + merge) on the smoke preset at several
//! worker counts, vs the FedAvg baseline — the headline L3 number in
//! EXPERIMENTS.md §Perf. With prebuilt HLO artifacts it drives PJRT;
//! otherwise it writes a native-exec manifest and drives the pure-Rust
//! FC executor, so the workers scaling is measurable on any host.

use std::path::PathBuf;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::{default_artifacts_dir, write_native_manifest, Runtime};
use feddd::util::bench::{black_box, Bencher};

fn artifacts_dir() -> PathBuf {
    // Use the prebuilt artifacts only when the runtime can actually open
    // them (with the vendored xla stub, a PJRT manifest errors at
    // Runtime::new); otherwise bench the native-exec runtime.
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() && Runtime::new(&dir).is_ok() {
        return dir;
    }
    // Fixed name (not pid-suffixed): repeated bench runs reuse the same
    // directory instead of leaking one per invocation.
    let tmp = std::env::temp_dir().join("feddd_round_bench_native");
    write_native_manifest(&tmp, &[("mlp", 1.0)], 16, 64).expect("native manifest");
    eprintln!(
        "prebuilt artifacts unavailable; benching the native-exec runtime ({})",
        tmp.display()
    );
    tmp
}

fn cfg(scheme: &str, workers: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.rounds = 1000; // stepped manually
    cfg.n_clients = 10;
    cfg.test_n = 128;
    cfg.workers = workers;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

fn main() {
    let dir = artifacts_dir();
    let mut b = Bencher::new("round");
    // headline: FedDD round vs worker count (1 = sequential baseline)
    for workers in [1usize, 2, 4] {
        let mut run = FedRun::new(cfg("feddd", workers, &dir)).unwrap();
        // warm caches & pass round 1 (full upload)
        run.step_round().unwrap();
        b.bench(&format!("step_round_feddd_mlp_10c_w{workers}"), || {
            black_box(run.step_round().unwrap());
        });
    }
    // FedAvg baseline (full uploads, no selection) at workers=1.
    let mut run = FedRun::new(cfg("fedavg", 1, &dir)).unwrap();
    run.step_round().unwrap();
    b.bench("step_round_fedavg_mlp_10c_w1", || {
        black_box(run.step_round().unwrap());
    });
    // evaluation pass
    let mut run = FedRun::new(cfg("feddd", 1, &dir)).unwrap();
    run.step_round().unwrap();
    b.bench("evaluate_mlp_128", || {
        black_box(run.evaluate().unwrap());
    });
    b.finish();
}
