//! End-to-end round bench: one full FedDD round (train + select +
//! shard-aggregate + merge) on the smoke preset, swept over
//! scheme × workers × round_mode, vs the FedAvg baseline — the headline
//! L3 number in EXPERIMENTS.md §Perf. With prebuilt HLO artifacts it
//! drives PJRT; otherwise it writes a native-exec manifest and drives the
//! pure-Rust FC executor, so the sweep is measurable on any host.
//!
//! With `FEDDD_BENCH_JSON=<dir>` the harness writes `BENCH_<name>.json`
//! (per case: ns/round + uploaded/wire bytes; run level: the sync vs
//! semi-async virtual-time comparison plus *deterministic* wire-volume
//! totals that `ci/bench_diff.py` gates against `BENCH_baseline/`). The
//! bench also **gates** inline: on the skewed Table-4 fleet, semi-async
//! quorum rounds must finish the same round count in strictly less
//! virtual time than the synchronous barrier, and a `value_plane=auto`
//! run must realize a strictly smaller wire total than the f32 run on
//! the same config (with the i8 plane actually engaging), or the
//! process exits non-zero (CI fails).

use std::path::PathBuf;

use feddd::codec::PlaneMix;
use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::{default_artifacts_dir, write_native_manifest, Runtime};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::json::Json;
use feddd::util::threadpool::total_threads_spawned;

fn artifacts_dir() -> PathBuf {
    // Use the prebuilt artifacts only when the runtime can actually open
    // them (with the vendored xla stub, a PJRT manifest errors at
    // Runtime::new); otherwise bench the native-exec runtime.
    let dir = default_artifacts_dir();
    if dir.join("manifest.json").exists() && Runtime::new(&dir).is_ok() {
        return dir;
    }
    // Fixed name (not pid-suffixed): repeated bench runs reuse the same
    // directory instead of leaking one per invocation.
    let tmp = std::env::temp_dir().join("feddd_round_bench_native");
    write_native_manifest(&tmp, &[("mlp", 1.0)], 16, 64).expect("native manifest");
    eprintln!(
        "prebuilt artifacts unavailable; benching the native-exec runtime ({})",
        tmp.display()
    );
    tmp
}

fn cfg(scheme: &str, workers: usize, round_mode: &str, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.rounds = 1000; // stepped manually
    cfg.n_clients = 10;
    cfg.test_n = 128;
    cfg.workers = workers;
    cfg.round_mode = round_mode.into();
    cfg.quorum = 0.7;
    cfg.staleness_beta = 0.5;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// Virtual time plus realized wire / payload volume and peak client-state
/// bytes after `rounds` rounds under the given round mode. Fully
/// deterministic (seeded, fixed round count — unlike the timed loops,
/// whose iteration counts depend on the host), so `ci/bench_diff.py`
/// gates on these byte totals *exactly*: any increase at the same config
/// (= same dropout schedule) fails CI.
fn deterministic_run(
    scheme: &str,
    round_mode: &str,
    plane: &str,
    rounds: usize,
    dir: &PathBuf,
) -> (f64, usize, usize, usize, PlaneMix) {
    let mut c = cfg(scheme, 1, round_mode, dir);
    c.value_plane = plane.into();
    let mut run = FedRun::new(c).unwrap();
    let mut wire = 0usize;
    let mut payload = 0usize;
    let mut peak_state = 0usize;
    let mut planes = PlaneMix::default();
    for _ in 0..rounds {
        let out = run.step_round().unwrap();
        wire += out.wire_bytes;
        payload += out.uploaded_bytes;
        peak_state = peak_state.max(out.client_state_bytes);
        planes.merge(out.planes);
    }
    (run.clock.now(), wire, payload, peak_state, planes)
}

fn main() {
    let dir = artifacts_dir();
    let mut b = Bencher::new("round");
    // Gate verdicts are collected and acted on only after b.finish() has
    // written BENCH_round.json — the CI diff step must always find it.
    let mut gate_failures: Vec<String> = Vec::new();
    // headline sweep: FedDD round wall-clock at scheme × workers ×
    // round_mode (workers=1 sync is the sequential baseline). Each case
    // also annotates `thread_spawns` — the OS threads the whole run
    // (construction + every timed round) cost. The persistent pool must
    // keep this ≤ workers, i.e. O(workers); the old spawn-per-call pool
    // paid O(micro-batches) here, thousands after the timed loop.
    for round_mode in ["sync", "semi_async"] {
        for workers in [1usize, 2, 4] {
            let spawned_before = total_threads_spawned();
            let mut run = FedRun::new(cfg("feddd", workers, round_mode, &dir)).unwrap();
            // warm caches & pass round 1 (full upload)
            run.step_round().unwrap();
            let mut last_uploaded = 0usize;
            let mut last_wire = 0usize;
            b.bench(&format!("step_round_feddd_mlp_10c_w{workers}_{round_mode}"), || {
                let out = black_box(run.step_round().unwrap());
                last_uploaded = out.uploaded_bytes;
                last_wire = out.wire_bytes;
            });
            let spawned = total_threads_spawned() - spawned_before;
            b.annotate("scheme", Json::s("feddd"));
            b.annotate("workers", Json::Num(workers as f64));
            b.annotate("round_mode", Json::s(round_mode));
            b.annotate("uploaded_bytes", Json::Num(last_uploaded as f64));
            b.annotate("case_wire_bytes", Json::Num(last_wire as f64));
            b.annotate("thread_spawns", Json::Num(spawned as f64));
            if spawned > workers {
                gate_failures.push(format!(
                    "step_round w{workers} {round_mode}: spawned {spawned} OS threads \
                     (> workers = {workers}); spawns must be O(workers), not O(micro-batches)"
                ));
            }
        }
    }
    // FedAvg baseline (full uploads, no selection) at workers=1.
    let spawned_before = total_threads_spawned();
    let mut run = FedRun::new(cfg("fedavg", 1, "sync", &dir)).unwrap();
    run.step_round().unwrap();
    let mut last_uploaded = 0usize;
    b.bench("step_round_fedavg_mlp_10c_w1_sync", || {
        last_uploaded = black_box(run.step_round().unwrap()).uploaded_bytes;
    });
    let spawned = total_threads_spawned() - spawned_before;
    b.annotate("scheme", Json::s("fedavg"));
    b.annotate("workers", Json::Num(1.0));
    b.annotate("round_mode", Json::s("sync"));
    b.annotate("uploaded_bytes", Json::Num(last_uploaded as f64));
    b.annotate("thread_spawns", Json::Num(spawned as f64));
    if spawned > 0 {
        gate_failures.push(format!(
            "fedavg w1: a sequential run spawned {spawned} OS threads (want 0)"
        ));
    }
    // evaluation pass
    let mut run = FedRun::new(cfg("feddd", 1, "sync", &dir)).unwrap();
    run.step_round().unwrap();
    b.bench("evaluate_mlp_128", || {
        black_box(run.evaluate().unwrap());
    });

    // ---- virtual-time gate (CI fails on regression) ----
    // On the skewed Table-4 fleet the quorum scheduler must close the
    // same number of rounds in strictly less virtual time than the
    // barrier. This is deterministic (seeded), so a violation is a real
    // scheduler regression, not noise.
    let rounds = 8;
    let (vt_sync, wire_sync, payload_sync, state_sync, _) =
        deterministic_run("feddd", "sync", "f32", rounds, &dir);
    let (vt_semi, wire_semi, payload_semi, state_semi, _) =
        deterministic_run("feddd", "semi_async", "f32", rounds, &dir);
    let speedup = vt_sync / vt_semi;
    println!(
        "round::virtual_time_{rounds}r  sync {vt_sync:.1}s  \
         semi_async {vt_semi:.1}s  speedup {speedup:.2}x"
    );
    println!(
        "round::wire_volume_{rounds}r  sync {wire_sync}B (payload {payload_sync}B)  \
         semi_async {wire_semi}B (payload {payload_semi}B)"
    );
    b.annotate_run("v_time_sync_s", Json::Num(vt_sync));
    b.annotate_run("v_time_semi_async_s", Json::Num(vt_semi));
    b.annotate_run("semi_async_speedup", Json::Num(speedup));
    // Deterministic byte totals: ci/bench_diff.py fails CI on *any*
    // increase of a `wire_*` / `payload_*` key vs the committed baseline.
    b.annotate_run("wire_bytes_sync_8r", Json::Num(wire_sync as f64));
    b.annotate_run("wire_bytes_semi_async_8r", Json::Num(wire_semi as f64));
    b.annotate_run("payload_bytes_sync_8r", Json::Num(payload_sync as f64));
    b.annotate_run("payload_bytes_semi_async_8r", Json::Num(payload_semi as f64));
    // Virtualized client-state footprint (per-client residuals + live
    // snapshots), gated like the wire totals: any increase fails CI.
    b.annotate_run("client_state_peak_bytes_sync_8r", Json::Num(state_sync as f64));
    b.annotate_run("client_state_peak_bytes_semi_async_8r", Json::Num(state_semi as f64));

    // ---- value-plane sweep (DESIGN.md §Codec) ----
    // Same config and seed as the sync f32 run above, but value_plane =
    // auto: per layer the codec picks the smallest plane whose realized
    // quantization error stays within plane_error · max|value|. All
    // totals below are deterministic; ci/bench_diff.py gates the
    // `wire_*` keys no-increase and the `plane_*` keys byte-exactly.
    let (_, wire_auto, payload_auto, _, mix_auto) =
        deterministic_run("feddd", "sync", "auto", rounds, &dir);
    println!(
        "round::plane_mix_{rounds}r  f32 {wire_sync}B  auto {wire_auto}B \
         (payload {payload_auto}B)  layers f32 {} f16 {} i8 {}",
        mix_auto.f32_layers, mix_auto.f16_layers, mix_auto.i8_layers
    );
    b.annotate_run("wire_bytes_auto_sync_8r", Json::Num(wire_auto as f64));
    b.annotate_run("payload_bytes_auto_sync_8r", Json::Num(payload_auto as f64));
    b.annotate_run("wire_f32_bytes_auto_8r", Json::Num(mix_auto.f32_bytes as f64));
    b.annotate_run("wire_f16_bytes_auto_8r", Json::Num(mix_auto.f16_bytes as f64));
    b.annotate_run("wire_i8_bytes_auto_8r", Json::Num(mix_auto.i8_bytes as f64));
    b.annotate_run("plane_f32_layers_auto_8r", Json::Num(mix_auto.f32_layers as f64));
    b.annotate_run("plane_f16_layers_auto_8r", Json::Num(mix_auto.f16_layers as f64));
    b.annotate_run("plane_i8_layers_auto_8r", Json::Num(mix_auto.i8_layers as f64));
    if wire_auto >= wire_sync {
        gate_failures.push(format!(
            "value_plane=auto wire total {wire_auto}B is not strictly below the \
             f32 run's {wire_sync}B on the same config"
        ));
    }
    if mix_auto.i8_layers == 0 {
        gate_failures.push(
            "value_plane=auto never picked the i8 plane on the smoke fleet — \
             the quantizer is not engaging"
                .into(),
        );
    }
    // ---- dropout-family wire totals (DESIGN.md §Baselines) ----
    // `fed_dropout` at its default rate 0.5 shrinks both directions of
    // the wire (random dispatch masks thin the download, masked uploads
    // thin the return path), so its deterministic total must sit strictly
    // below `fedavg` on the identical fleet and seed. Both totals are
    // gated no-increase by ci/bench_diff.py like every other `wire_*` /
    // `payload_*` key.
    let (_, wire_fd, payload_fd, _, _) =
        deterministic_run("fed_dropout", "sync", "f32", rounds, &dir);
    let (_, wire_avg, payload_avg, _, _) =
        deterministic_run("fedavg", "sync", "f32", rounds, &dir);
    println!(
        "round::dropout_family_{rounds}r  fed_dropout {wire_fd}B (payload {payload_fd}B)  \
         fedavg {wire_avg}B (payload {payload_avg}B)"
    );
    b.annotate_run("wire_bytes_fed_dropout_8r", Json::Num(wire_fd as f64));
    b.annotate_run("payload_bytes_fed_dropout_8r", Json::Num(payload_fd as f64));
    b.annotate_run("wire_bytes_fedavg_8r", Json::Num(wire_avg as f64));
    b.annotate_run("payload_bytes_fedavg_8r", Json::Num(payload_avg as f64));
    if wire_fd >= wire_avg {
        gate_failures.push(format!(
            "fed_dropout wire total {wire_fd}B is not strictly below fedavg's \
             {wire_avg}B at the default rate"
        ));
    }
    // Total OS threads the whole bench process ever spawned — a fixed
    // function of the swept worker counts (2+4 twice), never of round or
    // micro-batch counts. Observability only: the per-case gates above
    // already fail on any O(micro-batches) regression.
    b.annotate_run("thread_spawns_process_total", Json::Num(total_threads_spawned() as f64));
    b.finish();
    if vt_semi >= vt_sync {
        gate_failures.push(format!(
            "semi_async virtual time {vt_semi:.1}s is not faster than sync \
             {vt_sync:.1}s on the skewed fleet"
        ));
    }
    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILED: {f}");
        }
        std::process::exit(1);
    }
}
