//! End-to-end round bench: one full synchronous FedDD round (train +
//! select + aggregate + merge) on the smoke preset vs the FedAvg baseline
//! — the headline L3 number in EXPERIMENTS.md §Perf.

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::default_artifacts_dir;
use feddd::util::bench::{black_box, Bencher};

fn cfg(scheme: &str) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = scheme.into();
    cfg.rounds = 1000; // stepped manually
    cfg.n_clients = 10;
    cfg.test_n = 128;
    cfg.artifacts_dir = default_artifacts_dir().to_string_lossy().into_owned();
    cfg
}

fn main() {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping round bench");
        return;
    }
    let mut b = Bencher::new("round");
    for scheme in ["feddd", "fedavg"] {
        let mut run = FedRun::new(cfg(scheme)).unwrap();
        // warm the executable cache & pass round 1 (full upload)
        run.step_round().unwrap();
        b.bench(&format!("step_round_{scheme}_mlp_10c"), || {
            black_box(run.step_round().unwrap());
        });
    }
    // evaluation pass
    let mut run = FedRun::new(cfg("feddd")).unwrap();
    run.step_round().unwrap();
    b.bench("evaluate_mlp_128", || {
        black_box(run.evaluate().unwrap());
    });
    b.finish();
}
