//! Serve-mode transport bench: what the socket seam costs and what it
//! must never change. Three measurements on 127.0.0.1:
//!
//! * **handshake throughput** — sequential connect → HELLO → CONFIG
//!   round trips against a live acceptor, reported as
//!   `serve_conns_per_s` (report-only; loopback accept rates are too
//!   host-dependent to gate);
//! * **round-close latency** — a timed loopback run (two agent threads
//!   hosting a four-client fleet) stepping full rounds through the
//!   socket transport; the per-round close latencies land as
//!   `serve_round_close_p50_ns` / `serve_round_close_p99_ns`, which
//!   `ci/bench_diff.py` gates against the baseline at `--max-regress`;
//! * **loopback equivalence** — a fixed-seed, fixed-round-count serve
//!   run whose wire/payload totals and virtual clock must match the
//!   in-process run *exactly*. Gated twice: inline (any mismatch exits
//!   non-zero) and across commits via the `serve_*bytes*` keys in
//!   `BENCH_serve.json`.
//!
//! With `FEDDD_BENCH_JSON=<dir>` the harness writes `BENCH_serve.json`
//! there, like every other bench.

use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::thread;
use std::time::Instant;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::write_native_manifest;
use feddd::transport::frame::{read_frame, write_frame, ConfigFrame, Hello, FT_CONFIG, FT_HELLO};
use feddd::transport::{run_agent, AgentOpts, BoundServer, ServeOpts};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::json::Json;

fn artifacts_dir() -> PathBuf {
    // Fixed name (not pid-suffixed): repeated bench runs reuse the same
    // directory instead of leaking one per invocation.
    let tmp = std::env::temp_dir().join("feddd_serve_bench_native");
    write_native_manifest(&tmp, &[("mlp", 1.0)], 16, 64).expect("native manifest");
    tmp
}

fn cfg(dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::smoke();
    cfg.scheme = "feddd".into();
    cfg.rounds = 1000; // stepped manually
    cfg.n_clients = 4;
    cfg.local_steps = 2;
    cfg.batch = 16;
    cfg.test_n = 64;
    cfg.train_per_client = 60;
    cfg.workers = 1;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// Bind, spawn one agent thread per split, accept, and hand back the
/// socket-driven run (step it manually; `shutdown_transport` releases
/// the agents with DONE).
fn start_loopback(cfg: &ExpConfig) -> (FedRun, Vec<thread::JoinHandle<()>>) {
    let mut opts = ServeOpts::from_config(cfg);
    opts.listen = "127.0.0.1:0".into();
    let bound = BoundServer::bind(&opts).unwrap();
    let addr = bound.local_addr.to_string();
    let handles = [(0usize, Some(2usize)), (2, None)]
        .into_iter()
        .map(|(slot_start, slot_count)| {
            let agent = AgentOpts {
                connect: addr.clone(),
                slot_start,
                slot_count,
                overrides: Vec::new(),
            };
            thread::spawn(move || {
                run_agent(&agent).unwrap();
            })
        })
        .collect();
    let coordinator = bound.accept_agents(&opts, cfg).unwrap();
    let run = FedRun::with_transport(cfg.clone(), Box::new(coordinator)).unwrap();
    (run, handles)
}

/// Sequential connect → HELLO → CONFIG round trips against a live
/// acceptor speaking the real frame layer; returns connections/second.
fn handshake_throughput(cfg_json: &str) -> f64 {
    const CONNS: usize = 256;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let cfg_json = cfg_json.to_string();
    let server = thread::spawn(move || {
        for _ in 0..CONNS {
            let (mut s, _) = listener.accept().unwrap();
            s.set_nodelay(true).ok();
            let (ty, payload) = read_frame(&mut s, 64).unwrap();
            assert_eq!(ty, FT_HELLO);
            let hello = Hello::decode(&payload).unwrap();
            write_frame(
                &mut s,
                FT_CONFIG,
                &ConfigFrame::encode_parts(hello.slot_start, 1, &cfg_json),
            )
            .unwrap();
        }
    });
    let t0 = Instant::now();
    for _ in 0..CONNS {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_nodelay(true).ok();
        write_frame(&mut s, FT_HELLO, &Hello { slot_start: 0, slot_count: 1 }.encode()).unwrap();
        let (ty, _) = read_frame(&mut s, 1 << 20).unwrap();
        assert_eq!(ty, FT_CONFIG);
    }
    let dt = t0.elapsed().as_secs_f64();
    server.join().unwrap();
    CONNS as f64 / dt
}

/// Nearest-rank percentile of an ascending-sorted sample.
fn pct(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn main() {
    let dir = artifacts_dir();
    let mut b = Bencher::new("serve");
    let mut gate_failures: Vec<String> = Vec::new();

    // ---- handshake throughput (report-only) ----
    let conns_per_s = handshake_throughput(&cfg(&dir).to_json().to_string_compact());
    println!("serve::handshake_throughput  {conns_per_s:>28.0} conns/s");

    // ---- round-close latency over the socket transport ----
    let (mut run, handles) = start_loopback(&cfg(&dir));
    run.step_round().unwrap(); // warm caches & pass round 1 (full upload)
    let mut latencies = Vec::new();
    b.bench("serve_round_close_loopback_mlp_4c_2agents", || {
        let t = Instant::now();
        black_box(run.step_round().unwrap());
        latencies.push(t.elapsed().as_secs_f64());
    });
    run.shutdown_transport().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    latencies.sort_by(f64::total_cmp);
    let p50 = pct(&latencies, 0.50);
    let p99 = pct(&latencies, 0.99);
    b.annotate("agents", Json::Num(2.0));
    b.annotate_run("serve_round_close_p50_ns", Json::Num(p50 * 1e9));
    b.annotate_run("serve_round_close_p99_ns", Json::Num(p99 * 1e9));
    b.annotate_run("serve_conns_per_s", Json::Num(conns_per_s));

    // ---- loopback equivalence (inline gate + baseline keys) ----
    // Fixed seed, fixed round count: the socket transport must realize
    // the same wire/payload totals and the same virtual clock as the
    // in-process run, to the byte and to the bit.
    let rounds = 8;
    let (mut run, handles) = start_loopback(&cfg(&dir));
    let (mut wire_serve, mut payload_serve) = (0usize, 0usize);
    for _ in 0..rounds {
        let out = run.step_round().unwrap();
        wire_serve += out.wire_bytes;
        payload_serve += out.uploaded_bytes;
    }
    let vt_serve = run.clock.now();
    run.shutdown_transport().unwrap();
    for h in handles {
        h.join().unwrap();
    }
    let mut run = FedRun::new(cfg(&dir)).unwrap();
    let (mut wire_local, mut payload_local) = (0usize, 0usize);
    for _ in 0..rounds {
        let out = run.step_round().unwrap();
        wire_local += out.wire_bytes;
        payload_local += out.uploaded_bytes;
    }
    let vt_local = run.clock.now();
    println!(
        "serve::loopback_equivalence_{rounds}r  serve {wire_serve}B (payload {payload_serve}B)  \
         in-process {wire_local}B (payload {payload_local}B)"
    );
    b.annotate_run("serve_wire_bytes_loopback_8r", Json::Num(wire_serve as f64));
    b.annotate_run("serve_payload_bytes_loopback_8r", Json::Num(payload_serve as f64));
    if wire_serve != wire_local || payload_serve != payload_local {
        gate_failures.push(format!(
            "loopback serve realized {wire_serve}B wire / {payload_serve}B payload, \
             in-process realized {wire_local}B / {payload_local}B — the transport \
             changed what goes over the wire"
        ));
    }
    if vt_serve.to_bits() != vt_local.to_bits() {
        gate_failures.push(format!(
            "loopback virtual clock {vt_serve}s != in-process {vt_local}s — the \
             transport perturbed the simulation"
        ));
    }

    b.finish();
    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILED: {f}");
        }
        std::process::exit(1);
    }
}
