//! PJRT runtime benches: train/eval executions and literal marshalling —
//! the L3<->L2 boundary cost that the train_scan optimization targets.

use feddd::model::ModelSpec;
use feddd::runtime::{default_artifacts_dir, Runtime};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::rng::Rng;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts not built; skipping runtime benches");
        return;
    }
    let rt = Runtime::new(&dir).unwrap();
    let mut b = Bencher::new("runtime_exec");
    let mut rng = Rng::new(3);

    let spec = ModelSpec::get("mlp", 1.0).unwrap();
    let mut params = spec.init_params(&mut rng);
    let x: Vec<f32> = (0..16 * 784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let y: Vec<i32> = (0..16).map(|_| rng.below(10) as i32).collect();
    b.bench("train_step_mlp_b16", || {
        black_box(
            rt.train_step("mlp_w100_train", &mut params, &x, &y, 0.01).unwrap(),
        );
    });

    // fused 4-step scan vs 4 single steps
    let xs: Vec<f32> = (0..4 * 16 * 784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ys: Vec<i32> = (0..4 * 16).map(|_| rng.below(10) as i32).collect();
    b.bench("train_scan4_mlp_b16", || {
        black_box(
            rt.train_scan("mlp_w100_train_scan", &mut params, &xs, &ys, 0.01)
                .unwrap(),
        );
    });
    b.bench("train_4x_step_mlp_b16", || {
        for s in 0..4 {
            let xo = &x; // same batch; cost dominated by exec + marshalling
            let _ = s;
            black_box(
                rt.train_step("mlp_w100_train", &mut params, xo, &y, 0.01).unwrap(),
            );
        }
    });

    let xe: Vec<f32> = (0..64 * 784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let ye: Vec<i32> = (0..64).map(|_| rng.below(10) as i32).collect();
    b.bench("eval_batch_mlp_b64", || {
        black_box(rt.eval_batch("mlp_w100_eval", &params, &xe, &ye).unwrap());
    });

    // literal marshalling cost (1M f32)
    let big: Vec<f32> = (0..1_000_000).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    b.bench_throughput("lit_f32_1M", 1_000_000, || {
        black_box(rt.lit_f32(black_box(&big), &[1_000_000]).unwrap());
    });
    b.finish();

    let stats = rt.stats();
    eprintln!(
        "runtime stats: {} execs, {:.3}s exec, {} compiles ({:.2}s), {} MB h2d",
        stats.executions,
        stats.exec_seconds,
        stats.compiled,
        stats.compile_seconds,
        stats.h2d_bytes / 1_000_000
    );
}
