//! Aggregation benches (paper Eq. 4 hot path): rust vectorized backend vs
//! the Pallas masked_acc/masked_fin artifacts through PJRT, plus the raw
//! flat primitives. Regenerates the §Perf aggregation rows.

use feddd::aggregation::{AggBackend, Aggregator};
use feddd::model::ModelSpec;
use feddd::runtime::{default_artifacts_dir, Runtime};
use feddd::selection::ChannelMask;
use feddd::tensor::{axpy_masked, masked_div};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::rng::Rng;

fn main() {
    let mut b = Bencher::new("aggregation");
    let mut rng = Rng::new(0);

    // raw primitives on a 1M-element flat buffer
    let n = 1_000_000;
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mask: Vec<f32> = (0..n).map(|_| if rng.bool(0.5) { 1.0 } else { 0.0 }).collect();
    let mut num = vec![0.0f32; n];
    let den = mask.clone();
    let prev = w.clone();
    let mut out = vec![0.0f32; n];
    b.bench_throughput("axpy_masked_1M", n as u64, || {
        axpy_masked(black_box(&mut num), 2.0, black_box(&w), black_box(&mask));
    });
    b.bench_throughput("masked_div_1M", n as u64, || {
        masked_div(black_box(&mut out), &num, &den, &prev);
    });

    // full aggregator round: 10 clients, cnn2 (paper-width) masks
    let spec = ModelSpec::get("cnn2", 1.0).unwrap();
    let prev_p = spec.init_params(&mut rng);
    let clients: Vec<_> = (0..10).map(|_| spec.init_params(&mut rng)).collect();
    let masks: Vec<_> = (0..10)
        .map(|_| {
            feddd::selection::select_mask(
                feddd::selection::Policy::Random,
                &spec,
                &prev_p,
                &clients[0],
                None,
                0.4,
                &mut rng,
            )
            .to_elementwise(&spec)
        })
        .collect();
    b.bench("round_rust_cnn2_10clients", || {
        let mut agg = Aggregator::new(&spec, AggBackend::Rust);
        for (c, m) in clients.iter().zip(&masks) {
            agg.add_client(c, m, 1.0, None).unwrap();
        }
        black_box(agg.finalize(&prev_p, None).unwrap());
    });

    // sharded accumulation (the parallel round engine's layout): four
    // shards of ≤3 clients each, merged pairwise, then finalized
    b.bench("round_rust_cnn2_10clients_4shards", || {
        let mut shards = Vec::with_capacity(4);
        for chunk in clients.chunks(3).zip(masks.chunks(3)) {
            let mut shard = Aggregator::new(&spec, AggBackend::Rust);
            for (c, m) in chunk.0.iter().zip(chunk.1) {
                shard.add_client(c, m, 1.0, None).unwrap();
            }
            shards.push(shard);
        }
        let merged = Aggregator::merge(shards).unwrap();
        black_box(merged.finalize(&prev_p, None).unwrap());
    });

    // XLA backend (needs artifacts)
    if let Ok(rt) = Runtime::new(&default_artifacts_dir()) {
        b.bench("round_xla_cnn2_10clients", || {
            let mut agg = Aggregator::new(&spec, AggBackend::Xla);
            for (c, m) in clients.iter().zip(&masks) {
                agg.add_client(c, m, 1.0, Some(&rt)).unwrap();
            }
            black_box(agg.finalize(&prev_p, Some(&rt)).unwrap());
        });
    }

    // zero-copy wire folds: same 10 clients through encode + absorb_wire
    // (no elementwise expansion, no dense contribution buffers) — the
    // round engine's production path since the codec rework
    let channel_masks: Vec<_> = (0..10)
        .map(|_| {
            feddd::selection::select_mask(
                feddd::selection::Policy::Random,
                &spec,
                &prev_p,
                &clients[0],
                None,
                0.4,
                &mut rng,
            )
        })
        .collect();
    let uploads: Vec<_> = clients
        .iter()
        .zip(&channel_masks)
        .map(|(c, m)| feddd::codec::encode_upload(m, c, &spec))
        .collect();
    b.bench("round_wire_cnn2_10clients", || {
        let mut agg = Aggregator::new(&spec, AggBackend::Rust);
        for up in &uploads {
            agg.absorb_wire(up, 1.0).unwrap();
        }
        black_box(agg.finalize(&prev_p, None).unwrap());
    });
    b.annotate(
        "wire_bytes",
        feddd::util::json::Json::Num(uploads.iter().map(|u| u.wire_len()).sum::<usize>() as f64),
    );

    // client-side encode cost (gather + layout pick)
    b.bench("encode_upload_cnn2", || {
        black_box(feddd::codec::encode_upload(&channel_masks[0], &clients[0], &spec));
    });

    // mask expansion cost
    let cm = ChannelMask::full(&spec);
    b.bench("mask_expand_cnn2", || {
        black_box(cm.to_elementwise(&spec));
    });
    b.finish();
}
