//! Dropout-rate allocation benches (Eq. 16/17): the fast structured
//! solver vs the general simplex across fleet sizes.

use feddd::solver::{allocate_fast, allocate_lp, AllocInput, AllocParams};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::rng::Rng;

fn instance(n: usize, rng: &mut Rng) -> Vec<AllocInput> {
    (0..n)
        .map(|_| AllocInput {
            u_bytes: rng.range_f64(1e5, 7e6),
            t_cmp: rng.range_f64(0.05, 2.0),
            sec_per_byte: rng.range_f64(1e-6, 1e-3),
            re: rng.range_f64(0.0, 1.0),
        })
        .collect()
}

fn main() {
    let mut b = Bencher::new("solver");
    let p = AllocParams { d_max: 0.8, a_server: 0.6, delta: 1.0 };
    let mut rng = Rng::new(1);
    for n in [10usize, 100, 1000] {
        let inputs = instance(n, &mut rng);
        b.bench(&format!("fast_n{n}"), || {
            black_box(allocate_fast(black_box(&inputs), &p).unwrap());
        });
        if n <= 100 {
            b.bench(&format!("simplex_n{n}"), || {
                black_box(allocate_lp(black_box(&inputs), &p).unwrap());
            });
        }
    }
    b.finish();
}
