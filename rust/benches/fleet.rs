//! Fleet-scale bench + smoke for the virtualized client state
//! (DESIGN.md §Fleet-Virtualization): sweeps fleet sizes
//! {100, 1k, 10k, 50k} on the native executor and reports
//! `client_state_bytes` — the fleet's persistent footprint (per-client
//! residuals + live shared snapshots) that replaces the dense
//! O(clients · model) replica array.
//!
//! Two kinds of cases:
//!
//! * **timed** (100, 1k clients) — ns/round of the micro-batched round
//!   engine at fleet scale, with state-byte case annotations;
//! * **deterministic one-shots** (10k; 50k with `FEDDD_FLEET_FULL=1`) —
//!   fixed seed, fixed round count, so the emitted
//!   `client_state_*`-prefixed run-level byte totals are exactly
//!   reproducible and `ci/bench_diff.py` gates them like the `wire_*`
//!   totals (any increase fails CI).
//!
//! **Inline gate** (the CI fleet smoke): the 10k-client, 2-round run
//! under the `fleet` preset (h=1 broadcast-heavy production shape) must
//! complete with peak client-state bytes below **10% of
//! clients × model_size_bytes**, or the process exits non-zero. A
//! second deterministic case runs the delta path (h=5, sparse rounds) and
//! requires the residual footprint to stay strictly below the dense
//! fleet's — the complement-of-mask invariant.

use std::path::PathBuf;
use std::time::Instant;

use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::write_native_manifest;
use feddd::util::bench::{black_box, Bencher};
use feddd::util::json::Json;
use feddd::util::threadpool::total_threads_spawned;

fn artifacts_dir() -> PathBuf {
    // Fixed name (not pid-suffixed): repeated bench runs reuse the same
    // directory instead of leaking one per invocation.
    let tmp = std::env::temp_dir().join("feddd_fleet_bench_native");
    write_native_manifest(&tmp, &[("mlp", 0.25)], 8, 64).expect("native manifest");
    tmp
}

fn cfg(n_clients: usize, h: usize, rounds: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::fleet();
    cfg.n_clients = n_clients;
    cfg.h = h;
    cfg.rounds = rounds;
    cfg.eval_every = rounds;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// One deterministic fixed-seed, fixed-round run; returns
/// (peak end-of-round state bytes, final state bytes, peak residual-only
/// bytes, model bytes, wall seconds). State bytes are independent of
/// host timing, so these totals gate byte-exactly in CI.
fn deterministic_fleet(
    n_clients: usize,
    h: usize,
    rounds: usize,
    dir: &PathBuf,
    gates: &mut Vec<String>,
) -> (usize, usize, usize, usize, f64) {
    let spawned_before = total_threads_spawned();
    let mut run = FedRun::new(cfg(n_clients, h, rounds, dir)).unwrap();
    let model_bytes = run.clients[0].u_bytes();
    let wall0 = Instant::now();
    let mut peak_state = 0usize;
    let mut last_state = 0usize;
    let mut peak_residual = 0usize;
    for _ in 0..rounds {
        let out = run.step_round().unwrap();
        peak_state = peak_state.max(out.client_state_bytes);
        last_state = out.client_state_bytes;
        peak_residual = peak_residual.max(run.client_residual_bytes());
    }
    // Spawn invariant at fleet scale: `rounds` rounds over `n_clients`
    // clients dispatch thousands of micro-batches, yet the whole run may
    // spawn at most its pool (`workers = 0` ⇒ available parallelism).
    let spawned = total_threads_spawned() - spawned_before;
    if spawned > run.pool_workers() {
        gates.push(format!(
            "fleet {n_clients}c run spawned {spawned} OS threads \
             (> pool workers {}): O(micro-batches) spawning is back",
            run.pool_workers()
        ));
    }
    (peak_state, last_state, peak_residual, model_bytes, wall0.elapsed().as_secs_f64())
}

fn main() {
    let dir = artifacts_dir();
    let mut b = Bencher::new("fleet");
    // Gate verdicts are collected here and acted on only after
    // b.finish() has written BENCH_fleet.json — the CI diff step runs on
    // bench failure too and must always find the JSON.
    let mut gate_failures: Vec<String> = Vec::new();

    // ---- timed sweep: ns/round at small-to-mid fleet sizes ----
    for &n in &[100usize, 1000] {
        let spawned_before = total_threads_spawned();
        let mut run = FedRun::new(cfg(n, 1, 1000, &dir)).unwrap();
        run.step_round().unwrap(); // warm caches, pass round 1
        let mut state_bytes = 0usize;
        b.bench(&format!("step_round_fleet_mlp25_{n}c_h1"), || {
            let out = black_box(run.step_round().unwrap());
            state_bytes = out.client_state_bytes;
        });
        // Whole-run OS thread spawns: the persistent pool pays exactly
        // its size once, however many timed rounds (× micro-batches per
        // round) just executed.
        let spawned = total_threads_spawned() - spawned_before;
        b.annotate("n_clients", Json::Num(n as f64));
        b.annotate("client_state_bytes", Json::Num(state_bytes as f64));
        b.annotate(
            "dense_state_bytes",
            Json::Num((n * run.clients[0].u_bytes()) as f64),
        );
        b.annotate("thread_spawns", Json::Num(spawned as f64));
        if spawned > run.pool_workers() {
            gate_failures.push(format!(
                "fleet timed {n}c: spawned {spawned} OS threads (> pool workers {})",
                run.pool_workers()
            ));
        }
    }

    // ---- deterministic delta-path case: 1k clients, sparse rounds ----
    // h=5 keeps rounds 2..3 mask-sparse, so every client carries its
    // complement-of-mask residual — the footprint the virtualization
    // must keep strictly below the dense fleet's.
    let (peak_1k, final_1k, resid_1k, model_bytes, wall_1k) =
        deterministic_fleet(1000, 5, 3, &dir, &mut gate_failures);
    let dense_1k = 1000 * model_bytes;
    println!(
        "fleet::delta_1k_h5_3r  peak_state {peak_1k}B  final {final_1k}B  \
         residuals {resid_1k}B  dense {dense_1k}B  ({:.2}x below dense)  wall {wall_1k:.1}s",
        dense_1k as f64 / peak_1k.max(1) as f64
    );
    b.annotate_run("client_state_peak_bytes_1k_h5_3r", Json::Num(peak_1k as f64));
    b.annotate_run("client_state_final_bytes_1k_h5_3r", Json::Num(final_1k as f64));
    b.annotate_run("dense_state_bytes_1k", Json::Num(dense_1k as f64));
    if resid_1k == 0 {
        gate_failures
            .push("sparse rounds left no residual — the delta path never ran".into());
    } else if resid_1k >= dense_1k {
        gate_failures.push(format!(
            "residual state {resid_1k}B not strictly below the dense fleet {dense_1k}B"
        ));
    }

    // ---- the 10k-client fleet smoke (the CI acceptance gate) ----
    let (peak_10k, final_10k, _resid_10k, model_bytes, wall_10k) =
        deterministic_fleet(10_000, 1, 2, &dir, &mut gate_failures);
    let dense_10k = 10_000 * model_bytes;
    let limit = dense_10k / 10; // < 10% of clients × model_size_bytes
    println!(
        "fleet::smoke_10k_h1_2r  peak_state {peak_10k}B  final {final_10k}B  \
         dense {dense_10k}B  limit {limit}B  wall {wall_10k:.1}s"
    );
    b.annotate_run("client_state_peak_bytes_10k_h1_2r", Json::Num(peak_10k as f64));
    b.annotate_run("client_state_final_bytes_10k_h1_2r", Json::Num(final_10k as f64));
    b.annotate_run("dense_state_bytes_10k", Json::Num(dense_10k as f64));
    b.annotate_run("fleet_smoke_wall_s", Json::Num(wall_10k));

    // ---- optional 50k sweep point (slow; opt-in, not part of the CI
    // quick run, so its keys never enter the baseline key set) ----
    if std::env::var("FEDDD_FLEET_FULL").is_ok() {
        let (peak_50k, final_50k, _r, mb, wall_50k) =
            deterministic_fleet(50_000, 1, 2, &dir, &mut gate_failures);
        println!(
            "fleet::smoke_50k_h1_2r  peak_state {peak_50k}B  final {final_50k}B  \
             dense {}B  wall {wall_50k:.1}s",
            50_000 * mb
        );
        b.annotate_run("client_state_peak_bytes_50k_h1_2r", Json::Num(peak_50k as f64));
    }

    if peak_10k >= limit {
        gate_failures.push(format!(
            "10k-client fleet smoke peak client-state {peak_10k}B is not below \
             10% of the dense fleet ({limit}B)"
        ));
    }
    // Whole-process spawn total (observability; the per-run gates above
    // are what fail on an O(micro-batches) regression).
    b.annotate_run("thread_spawns_process_total", Json::Num(total_threads_spawned() as f64));
    b.finish();
    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILED: {f}");
        }
        std::process::exit(1);
    }
}
