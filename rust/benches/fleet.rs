//! Fleet-scale bench + smoke for the virtualized fleet
//! (DESIGN.md §Fleet-Virtualization): sweeps fleet sizes
//! {100, 1k, 100k; 1M with `FEDDD_FLEET_FULL=1`} on the native executor
//! and reports the three virtualized planes per run:
//!
//! * `client_state_bytes` — per-client residuals + live shared
//!   snapshots + in-flight pending uploads (replaces the dense
//!   O(clients · model) replica array);
//! * `sim_state_bytes` — device profiles + per-client clocks + the
//!   arrival heap (O(fleet) scalars);
//! * `data_state_bytes` — lazy dataset store + shared partition + owned
//!   shard indices (O(prototypes + samples·8), never O(samples · dim)).
//!
//! Two kinds of cases:
//!
//! * **timed** (100, 1k clients) — ns/round of the micro-batched round
//!   engine at fleet scale, with state-byte case annotations;
//! * **deterministic one-shots** (100k; 1M with `FEDDD_FLEET_FULL=1`) —
//!   fixed seed, fixed round count, so the emitted `client_state_*` /
//!   `sim_state_*` / `data_state_*` run-level byte totals are exactly
//!   reproducible and `ci/bench_diff.py` gates them like the `wire_*`
//!   totals (any increase fails CI).
//!
//! **Inline gates** (the CI fleet smoke): the 100k-client, 2-round run
//! under the `fleet` preset (h=1 broadcast-heavy production shape) must
//! complete with peak client-state bytes below **10% of
//! clients × model_size_bytes**, and the *combined* resident footprint
//! (client + sim + data planes) below the same 10% yardstick — the
//! strictly-sublinear memory gate — or the process exits non-zero. A
//! second deterministic case runs the delta path (h=5, sparse rounds) and
//! requires the residual footprint to stay strictly below the dense
//! fleet's — the complement-of-mask invariant. The opt-in 1M case
//! additionally runs its round twice at different worker counts and
//! requires bitwise-identical losses, durations and global parameters.

use std::path::PathBuf;
use std::time::Instant;

use feddd::codec::PlaneMix;
use feddd::config::ExpConfig;
use feddd::coordinator::FedRun;
use feddd::runtime::write_native_manifest;
use feddd::util::bench::{black_box, Bencher};
use feddd::util::json::Json;
use feddd::util::threadpool::total_threads_spawned;

fn artifacts_dir() -> PathBuf {
    // Fixed name (not pid-suffixed): repeated bench runs reuse the same
    // directory instead of leaking one per invocation.
    let tmp = std::env::temp_dir().join("feddd_fleet_bench_native");
    write_native_manifest(&tmp, &[("mlp", 0.25)], 8, 64).expect("native manifest");
    tmp
}

fn cfg(n_clients: usize, h: usize, rounds: usize, dir: &PathBuf) -> ExpConfig {
    let mut cfg = ExpConfig::fleet();
    cfg.n_clients = n_clients;
    cfg.h = h;
    cfg.rounds = rounds;
    cfg.eval_every = rounds;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    cfg
}

/// Byte accounting of one deterministic fleet run. Every field is
/// independent of host timing, so the totals gate byte-exactly in CI.
struct FleetStats {
    /// Peak end-of-round client-state bytes.
    peak_state: usize,
    /// Final-round client-state bytes.
    final_state: usize,
    /// Peak residual-only bytes (the per-client persistent part).
    peak_residual: usize,
    /// Peak simulation-runtime bytes.
    peak_sim: usize,
    /// Data-plane bytes (constant across rounds).
    data_bytes: usize,
    /// One client's dense model size (the yardstick unit).
    model_bytes: usize,
    /// Wire value-plane mix over every upload of the run.
    planes: PlaneMix,
    wall_s: f64,
}

/// One deterministic fixed-seed, fixed-round run at the given worker
/// count (`None` ⇒ the preset's `workers = 0` auto width).
fn deterministic_fleet(
    n_clients: usize,
    h: usize,
    rounds: usize,
    workers: Option<usize>,
    dir: &PathBuf,
    gates: &mut Vec<String>,
) -> (FleetStats, Vec<u64>, Vec<Vec<f32>>) {
    let spawned_before = total_threads_spawned();
    let mut c = cfg(n_clients, h, rounds, dir);
    if let Some(w) = workers {
        c.workers = w;
    }
    let mut run = FedRun::new(c).unwrap();
    let model_bytes = run.clients[0].u_bytes();
    let wall0 = Instant::now();
    let mut stats = FleetStats {
        peak_state: 0,
        final_state: 0,
        peak_residual: 0,
        peak_sim: 0,
        data_bytes: run.data_state_bytes(),
        model_bytes,
        planes: PlaneMix::default(),
        wall_s: 0.0,
    };
    // Bitwise digest of the run: per-round loss/duration bits (the
    // cross-worker identity check of the opt-in 1M case).
    let mut digest: Vec<u64> = Vec::new();
    for _ in 0..rounds {
        let out = run.step_round().unwrap();
        stats.peak_state = stats.peak_state.max(out.client_state_bytes);
        stats.final_state = out.client_state_bytes;
        stats.peak_residual = stats.peak_residual.max(run.client_residual_bytes());
        stats.peak_sim = stats.peak_sim.max(out.sim_state_bytes);
        stats.planes.merge(out.planes);
        digest.push(out.mean_loss.to_bits());
        digest.push(out.duration.to_bits());
    }
    stats.wall_s = wall0.elapsed().as_secs_f64();
    // Spawn invariant at fleet scale: `rounds` rounds over `n_clients`
    // clients dispatch thousands of micro-batches, yet the whole run may
    // spawn at most its pool (`workers = 0` ⇒ available parallelism).
    let spawned = total_threads_spawned() - spawned_before;
    if spawned > run.pool_workers() {
        gates.push(format!(
            "fleet {n_clients}c run spawned {spawned} OS threads \
             (> pool workers {}): O(micro-batches) spawning is back",
            run.pool_workers()
        ));
    }
    let globals: Vec<Vec<f32>> =
        run.global_params.iter().map(|t| t.data().to_vec()).collect();
    (stats, digest, globals)
}

fn main() {
    let dir = artifacts_dir();
    let mut b = Bencher::new("fleet");
    // Gate verdicts are collected here and acted on only after
    // b.finish() has written BENCH_fleet.json — the CI diff step runs on
    // bench failure too and must always find the JSON.
    let mut gate_failures: Vec<String> = Vec::new();

    // ---- timed sweep: ns/round at small-to-mid fleet sizes ----
    for &n in &[100usize, 1000] {
        let spawned_before = total_threads_spawned();
        let mut run = FedRun::new(cfg(n, 1, 1000, &dir)).unwrap();
        run.step_round().unwrap(); // warm caches, pass round 1
        let mut state_bytes = 0usize;
        b.bench(&format!("step_round_fleet_mlp25_{n}c_h1"), || {
            let out = black_box(run.step_round().unwrap());
            state_bytes = out.client_state_bytes;
        });
        // Whole-run OS thread spawns: the persistent pool pays exactly
        // its size once, however many timed rounds (× micro-batches per
        // round) just executed.
        let spawned = total_threads_spawned() - spawned_before;
        b.annotate("n_clients", Json::Num(n as f64));
        b.annotate("client_state_bytes", Json::Num(state_bytes as f64));
        b.annotate(
            "dense_state_bytes",
            Json::Num((n * run.clients[0].u_bytes()) as f64),
        );
        b.annotate("thread_spawns", Json::Num(spawned as f64));
        if spawned > run.pool_workers() {
            gate_failures.push(format!(
                "fleet timed {n}c: spawned {spawned} OS threads (> pool workers {})",
                run.pool_workers()
            ));
        }
    }

    // ---- deterministic delta-path case: 1k clients, sparse rounds ----
    // h=5 keeps rounds 2..3 mask-sparse, so every client carries its
    // complement-of-mask residual — the footprint the virtualization
    // must keep strictly below the dense fleet's.
    let (s1k, _, _) = deterministic_fleet(1000, 5, 3, None, &dir, &mut gate_failures);
    let dense_1k = 1000 * s1k.model_bytes;
    println!(
        "fleet::delta_1k_h5_3r  peak_state {}B  final {}B  residuals {}B  \
         sim {}B  data {}B  dense {dense_1k}B  ({:.2}x below dense)  wall {:.1}s",
        s1k.peak_state,
        s1k.final_state,
        s1k.peak_residual,
        s1k.peak_sim,
        s1k.data_bytes,
        dense_1k as f64 / s1k.peak_state.max(1) as f64,
        s1k.wall_s
    );
    b.annotate_run("client_state_peak_bytes_1k_h5_3r", Json::Num(s1k.peak_state as f64));
    b.annotate_run("client_state_final_bytes_1k_h5_3r", Json::Num(s1k.final_state as f64));
    b.annotate_run("sim_state_peak_bytes_1k_h5_3r", Json::Num(s1k.peak_sim as f64));
    b.annotate_run("data_state_bytes_1k_h5_3r", Json::Num(s1k.data_bytes as f64));
    b.annotate_run("dense_state_bytes_1k", Json::Num(dense_1k as f64));
    // Fleet preset default keeps the wire at full precision; the layer
    // count is deterministic and gated byte-exactly (`plane_` prefix).
    b.annotate_run("plane_f32_layers_1k_h5_3r", Json::Num(s1k.planes.f32_layers as f64));
    if s1k.planes.f16_layers + s1k.planes.i8_layers != 0 {
        gate_failures.push(format!(
            "fleet preset default encoded {} f16 / {} i8 layers — the default wire \
             must stay full-precision f32",
            s1k.planes.f16_layers, s1k.planes.i8_layers
        ));
    }
    if s1k.peak_residual == 0 {
        gate_failures
            .push("sparse rounds left no residual — the delta path never ran".into());
    } else if s1k.peak_residual >= dense_1k {
        gate_failures.push(format!(
            "residual state {}B not strictly below the dense fleet {dense_1k}B",
            s1k.peak_residual
        ));
    }

    // ---- the 100k-client fleet smoke (the CI acceptance gate) ----
    let (s100k, _, _) = deterministic_fleet(100_000, 1, 2, None, &dir, &mut gate_failures);
    let dense_100k = 100_000 * s100k.model_bytes;
    let limit = dense_100k / 10; // < 10% of clients × model_size_bytes
    let combined = s100k.peak_state + s100k.peak_sim + s100k.data_bytes;
    println!(
        "fleet::smoke_100k_h1_2r  peak_state {}B  final {}B  sim {}B  data {}B  \
         combined {combined}B  dense {dense_100k}B  limit {limit}B  wall {:.1}s",
        s100k.peak_state, s100k.final_state, s100k.peak_sim, s100k.data_bytes, s100k.wall_s
    );
    b.annotate_run("client_state_peak_bytes_100k_h1_2r", Json::Num(s100k.peak_state as f64));
    b.annotate_run(
        "client_state_final_bytes_100k_h1_2r",
        Json::Num(s100k.final_state as f64),
    );
    b.annotate_run("sim_state_peak_bytes_100k_h1_2r", Json::Num(s100k.peak_sim as f64));
    b.annotate_run("data_state_bytes_100k_h1_2r", Json::Num(s100k.data_bytes as f64));
    b.annotate_run("dense_state_bytes_100k", Json::Num(dense_100k as f64));
    b.annotate_run("fleet_smoke_wall_s", Json::Num(s100k.wall_s));
    if s100k.peak_state >= limit {
        gate_failures.push(format!(
            "100k-client fleet smoke peak client-state {}B is not below \
             10% of the dense fleet ({limit}B)",
            s100k.peak_state
        ));
    }
    if combined >= limit {
        gate_failures.push(format!(
            "100k-client combined resident footprint {combined}B (client + sim + data) \
             is not below 10% of the dense fleet ({limit}B): some plane regressed to \
             O(clients x model)"
        ));
    }

    // ---- optional 1M-client round (slow; opt-in, not part of the CI
    // quick run, so its keys never enter the baseline key set) ----
    // Run the same single round at two worker counts: the memory gate
    // must hold at megafleet scale AND the round must be bitwise
    // identical — the determinism contract does not decay with n.
    if std::env::var("FEDDD_FLEET_FULL").is_ok() {
        let (s1m, digest_a, globals_a) =
            deterministic_fleet(1_000_000, 1, 1, Some(2), &dir, &mut gate_failures);
        let (_, digest_b, globals_b) =
            deterministic_fleet(1_000_000, 1, 1, Some(4), &dir, &mut gate_failures);
        let dense_1m = 1_000_000 * s1m.model_bytes;
        let limit_1m = dense_1m / 10;
        let combined_1m = s1m.peak_state + s1m.peak_sim + s1m.data_bytes;
        println!(
            "fleet::smoke_1m_h1_1r  peak_state {}B  sim {}B  data {}B  \
             combined {combined_1m}B  dense {dense_1m}B  limit {limit_1m}B  wall {:.1}s",
            s1m.peak_state, s1m.peak_sim, s1m.data_bytes, s1m.wall_s
        );
        b.annotate_run("client_state_peak_bytes_1m_h1_1r", Json::Num(s1m.peak_state as f64));
        b.annotate_run("sim_state_peak_bytes_1m_h1_1r", Json::Num(s1m.peak_sim as f64));
        b.annotate_run("data_state_bytes_1m_h1_1r", Json::Num(s1m.data_bytes as f64));
        if combined_1m >= limit_1m {
            gate_failures.push(format!(
                "1M-client combined resident footprint {combined_1m}B is not below \
                 10% of the dense fleet ({limit_1m}B)"
            ));
        }
        if digest_a != digest_b {
            gate_failures
                .push("1M-client round loss/duration digest differs across worker counts".into());
        }
        if globals_a != globals_b {
            gate_failures
                .push("1M-client round global parameters differ across worker counts".into());
        }
    }

    // Whole-process spawn total (observability; the per-run gates above
    // are what fail on an O(micro-batches) regression).
    b.annotate_run("thread_spawns_process_total", Json::Num(total_threads_spawned() as f64));
    b.finish();
    if !gate_failures.is_empty() {
        for f in &gate_failures {
            eprintln!("GATE FAILED: {f}");
        }
        std::process::exit(1);
    }
}
