//! Uploaded-parameter selection benches (Algorithm 2): per-policy scoring
//! + top-k masking cost on the paper's CNN2.

use feddd::model::ModelSpec;
use feddd::selection::{select_mask, Policy};
use feddd::util::bench::{black_box, Bencher};
use feddd::util::rng::Rng;

fn main() {
    let mut b = Bencher::new("selection");
    let spec = ModelSpec::get("cnn2", 1.0).unwrap();
    let mut rng = Rng::new(2);
    let before = spec.init_params(&mut rng);
    let after = spec.init_params(&mut rng);
    for (name, policy) in [
        ("importance", Policy::Importance),
        ("max", Policy::Max),
        ("delta", Policy::Delta),
        ("random", Policy::Random),
        ("ordered", Policy::Ordered),
    ] {
        b.bench(&format!("cnn2_{name}_d0.6"), || {
            black_box(select_mask(
                policy,
                &spec,
                black_box(&before),
                black_box(&after),
                None,
                0.6,
                &mut rng,
            ));
        });
    }
    b.finish();
}
