"""Pallas kernel for the FedDD importance index (Eq. 20/21), elementwise
part: |ΔW * (W + ΔW) / W|, with the divide-by-zero guard described in
DESIGN.md (|W| < eps is clamped to sign(W)*eps).

The per-channel/neuron reduction (‖·‖_(k)) and the coverage-rate division
(Eq. 21) are group-structured (group sizes vary per layer); the reduction
is done by the caller — rust-side over the flat scores, or jnp in the
reference model path — while this kernel owns the elementwise hot loop.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES

EPS = 1e-8


def _importance_kernel(w_ref, dw_ref, o_ref):
    w = w_ref[...]
    dw = dw_ref[...]
    sign = jnp.where(w >= 0.0, 1.0, -1.0)
    w_safe = jnp.where(jnp.abs(w) < EPS, sign * EPS, w)
    o_ref[...] = jnp.abs(dw * (w + dw) / w_safe)


def importance_flat(w: jax.Array, dw: jax.Array) -> jax.Array:
    """Elementwise importance scores over flat f32[F], F % 1024 == 0."""
    f = w.shape[0]
    tiles = f // _TILE
    shape2 = (f // _LANES, _LANES)
    spec = pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _importance_kernel,
        grid=(tiles,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape2, jnp.float32),
        interpret=True,
    )(w.reshape(shape2), dw.reshape(shape2))
    return out.reshape(f)
