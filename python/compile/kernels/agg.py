"""Pallas kernels for the FedDD masked aggregation hot path (Eq. 4).

The server-side aggregation
    W^t = (sum_n m_n * Ŵ_n ⊙ M_n) / (sum_n m_n * M_n)
is streamed client-by-client over flat f32 parameter chunks:

  * `masked_acc`  — one client's contribution fused into the running
    numerator/denominator accumulators:
        num' = num + m_n * (w ⊙ mask)
        den' = den + m_n * mask
  * `masked_fin`  — the finalize pass with the zero-coverage rule
    (positions uploaded by no client keep the previous global value):
        out = where(den > 0, num / den, prev)

Pure VPU elementwise work; tiles are (8, 128) lanes over the flattened
chunk, the natural TPU vector shape. The rust coordinator calls these via
the AOT artifacts (`--agg-backend xla`) or uses its own vectorized loops
(`--agg-backend rust`); both are cross-checked in tests.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Flat chunk is reshaped to (rows, 1024) tiles of (8, 128).
_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _acc_kernel(num_ref, den_ref, w_ref, mask_ref, mn_ref, onum_ref, oden_ref):
    mn = mn_ref[0]
    masked = w_ref[...] * mask_ref[...]
    onum_ref[...] = num_ref[...] + mn * masked
    oden_ref[...] = den_ref[...] + mn * mask_ref[...]


def _fin_kernel(num_ref, den_ref, prev_ref, o_ref):
    den = den_ref[...]
    safe = jnp.where(den > 0.0, den, 1.0)
    o_ref[...] = jnp.where(den > 0.0, num_ref[...] / safe, prev_ref[...])


def _as_tiles(x: jax.Array) -> jax.Array:
    (f,) = x.shape
    assert f % _TILE == 0, f"chunk size {f} must be a multiple of {_TILE}"
    return x.reshape(f // _SUBLANES // _LANES * _SUBLANES, _LANES)


def masked_acc(num, den, w, mask, mn):
    """Accumulate one client's masked contribution.

    All of `num, den, w, mask` are flat f32[F] with F % 1024 == 0; `mn` is
    f32[1] (the client's aggregation weight m_n). Returns (num', den').
    """
    f = num.shape[0]
    tiles = f // _TILE
    args = [_as_tiles(a) for a in (num, den, w, mask)]
    grid = (tiles,)
    spec = pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0))
    mn_spec = pl.BlockSpec((1,), lambda i: (0,))
    onum, oden = pl.pallas_call(
        _acc_kernel,
        grid=grid,
        in_specs=[spec, spec, spec, spec, mn_spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(args[0].shape, jnp.float32),
            jax.ShapeDtypeStruct(args[0].shape, jnp.float32),
        ],
        interpret=True,
    )(*args, mn)
    return onum.reshape(f), oden.reshape(f)


def masked_fin(num, den, prev):
    """Finalize: elementwise num/den where covered, else keep `prev`."""
    f = num.shape[0]
    tiles = f // _TILE
    args = [_as_tiles(a) for a in (num, den, prev)]
    spec = pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _fin_kernel,
        grid=(tiles,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(args[0].shape, jnp.float32),
        interpret=True,
    )(*args)
    return out.reshape(f)
