"""Pallas SGD update kernel: w' = w - lr * g over flat f32 chunks.

Used by the L2 train_step's parameter update epilogue and exported as a
standalone flat artifact for the rust-side optimizer path tests.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_LANES = 128
_SUBLANES = 8
_TILE = _LANES * _SUBLANES


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


def sgd_update(w: jax.Array, g: jax.Array, lr: jax.Array) -> jax.Array:
    """w, g: flat f32[F] with F % 1024 == 0; lr: f32[1]."""
    f = w.shape[0]
    tiles = f // _TILE
    shape2 = (f // _LANES, _LANES)
    spec = pl.BlockSpec((_SUBLANES, _LANES), lambda i: (i, 0))
    lr_spec = pl.BlockSpec((1,), lambda i: (0,))
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(tiles,),
        in_specs=[spec, spec, lr_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape2, jnp.float32),
        interpret=True,
    )(w.reshape(shape2), g.reshape(shape2), lr)
    return out.reshape(f)
