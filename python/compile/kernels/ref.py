"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
pytest compares against; see python/tests/test_kernels.py)."""

import jax.numpy as jnp

EPS = 1e-8


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dense_ref(x, w, b):
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def masked_acc_ref(num, den, w, mask, mn):
    mn = mn[0]
    return num + mn * (w * mask), den + mn * mask


def masked_fin_ref(num, den, prev):
    safe = jnp.where(den > 0.0, den, 1.0)
    return jnp.where(den > 0.0, num / safe, prev)


def importance_ref(w, dw):
    sign = jnp.where(w >= 0.0, 1.0, -1.0)
    w_safe = jnp.where(jnp.abs(w) < EPS, sign * EPS, w)
    return jnp.abs(dw * (w + dw) / w_safe)


def sgd_update_ref(w, g, lr):
    return w - lr[0] * g
