# L1: Pallas kernels (interpret=True — lowered to plain HLO so the rust
# PJRT CPU client can execute them; real-TPU lowering would emit Mosaic
# custom-calls the CPU plugin cannot run).
from .dense import dense, matmul_pallas
from .agg import masked_acc, masked_fin
from .importance import importance_flat
from .update import sgd_update

__all__ = [
    "dense",
    "matmul_pallas",
    "masked_acc",
    "masked_fin",
    "importance_flat",
    "sgd_update",
]
