"""Pallas tiled matmul + fused-bias dense layer (L1 hot-spot).

The paper's models spend their FLOPs in dense (FC) and conv layers. The FC
layers are implemented here as a Pallas kernel so they lower into the same
HLO module as the surrounding jax program (L2) and run from the rust PJRT
client.

Hardware adaptation (paper targets CUDA GPUs, we target the TPU mental
model per DESIGN.md §Hardware-Adaptation):
  * grid = (M/bm, N/bn, K/bk) — the K axis is the innermost, sequential
    grid dimension; the output block is revisited and accumulated in place,
    which on a real TPU keeps the accumulator resident in VMEM.
  * tiles are MXU-aligned (bm,bn,bk multiples of 8/128 after padding);
    `jnp.dot(..., preferred_element_type=f32)` targets the MXU systolic
    array rather than the VPU.
  * bias add is fused into the final K step (epilogue) — one HBM write.

`interpret=True` everywhere: on this CPU-only image the kernel is lowered
through the pallas interpreter into plain HLO ops; numerics are identical
to what the Mosaic path would compute.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. Kept modest so that smoke-scale models (M=16 batch) do not
# explode padding, while staying MXU-shaped (last dim 128).
_BM = 32
_BN = 128
_BK = 128


def _pad_to(x: jax.Array, mult0: int, mult1: int) -> jax.Array:
    m, n = x.shape
    pm = (-m) % mult0
    pn = (-n) % mult1
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """One (bm, bn) output tile; K-axis accumulated across grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def matmul_pallas(x: jax.Array, w: jax.Array) -> jax.Array:
    """`x @ w` via the tiled Pallas kernel. f32[M,K] @ f32[K,N] -> f32[M,N]."""
    assert x.ndim == 2 and w.ndim == 2 and x.shape[1] == w.shape[0], (
        x.shape,
        w.shape,
    )
    m, k = x.shape
    _, n = w.shape
    bm = min(_BM, max(8, m))
    xp = _pad_to(x, bm, _BK)
    wp = _pad_to(w, _BK, _BN)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    k_steps = kp // _BK
    grid = (mp // bm, np_ // _BN, k_steps)
    out = pl.pallas_call(
        partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, _BK), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((_BK, _BN), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, _BN), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Fully-connected layer `x @ w + b` with both passes on the Pallas
    matmul (pallas_call has no automatic VJP, so we provide one whose
    backward matmuls also go through the kernel)."""
    return matmul_pallas(x, w) + b


def _dense_fwd(x, w, b):
    return dense(x, w, b), (x, w)


def _dense_bwd(res, g):
    x, w = res
    dx = matmul_pallas(g, w.T)
    dw = matmul_pallas(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
