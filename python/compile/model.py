"""L2: the paper's models (Tables 2, 3, 6) in JAX, calling the L1 Pallas
kernels for the dense layers, plus train/eval step functions that aot.py
lowers to HLO text for the rust runtime.

Model registry
--------------
* ``mlp``   — MNIST   MLP  FC(784,100)-ReLU-FC(100,64)-ReLU-FC(64,10)
* ``cnn1``  — FMNIST  Conv(1,10,k5)v-pool-ReLU / Conv(10,20,k5)v-pool-ReLU /
              FC(320,50)-ReLU / FC(50,10)                     (VALID convs)
* ``cnn2``  — CIFAR10 Conv(3,16,k3)s-ReLU-pool ×3 (16/32/64) /
              FC(1024,500)-ReLU / FC(500,100)-ReLU / FC(100,10) (SAME convs)
* ``het_a_1..5`` / ``het_b_1..5`` — the five heterogeneous VGG-style
  sub-models of Table 3 / Table 6 (5× conv-pool, 3× FC, SAME convs).

Note on Tables 3/6: the paper lists FC(512, ·) for every sub-model even
where the final conv stage has ≠512 channels (e.g. het_b_5 ends at 256);
we compute the FC input from the actual conv output (32→5 pools→1×1
spatial), which is the only shape-consistent reading. Documented in
DESIGN.md §6.

``width_mult`` scales every hidden dimension (never the input or the 10
output classes): ``s = max(4, round4(round(ch*mult)))`` — the rust model
registry implements the identical formula and an integration test pins
the two against the artifact manifest.
"""

from dataclasses import dataclass
from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import dense

NUM_CLASSES = 10


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class Conv:
    in_ch: int
    out_ch: int
    kernel: int
    padding: str  # "SAME" | "VALID"
    pool_first: bool  # CNN1 pools before ReLU (per Table 2 row order)


@dataclass(frozen=True)
class Fc:
    in_dim: int
    out_dim: int
    relu: bool


@dataclass(frozen=True)
class ModelSpec:
    name: str
    width: float
    input_shape: Tuple[int, ...]  # (784,) for MLP, (C,H,W) for CNNs
    layers: Tuple  # Conv | Fc


def _round4(ch: int, mult: float) -> int:
    if mult == 1.0:
        return ch  # paper-exact at full width (Tables 2/3/6)
    s = max(1, int(round(ch * mult)))
    return max(4, ((s + 3) // 4) * 4)


def _spatial_after(hw: int, kernel: int, padding: str, pools: int) -> int:
    for _ in range(pools):
        if padding == "VALID":
            hw = hw - (kernel - 1)
        hw = hw // 2
    return hw


def _vgg_spec(
    name: str,
    conv_ch: List[int],
    fc_hidden: List[int],
    width: float,
) -> ModelSpec:
    """5× (conv SAME k3 + pool + relu) then 3× FC, input 3×32×32."""
    chans = [_round4(c, width) for c in conv_ch]
    hidden = [_round4(h, width) for h in fc_hidden]
    layers = []
    in_ch = 3
    for c in chans:
        layers.append(Conv(in_ch, c, 3, "SAME", pool_first=False))
        in_ch = c
    # 32 -> 16 -> 8 -> 4 -> 2 -> 1 after five pools
    fc_in = chans[-1] * 1 * 1
    dims = [fc_in] + hidden + [NUM_CLASSES]
    for i in range(len(dims) - 1):
        layers.append(Fc(dims[i], dims[i + 1], relu=(i < len(dims) - 2)))
    return ModelSpec(name, width, (3, 32, 32), tuple(layers))


# Channel plans straight from Tables 3 and 6.
_HET_A = {
    1: ([64, 128, 256, 512, 512], [100, 100]),
    2: ([64, 128, 256, 256, 512], [100, 100]),
    3: ([64, 128, 256, 256, 512], [80, 100]),
    4: ([32, 128, 256, 256, 512], [80, 100]),
    5: ([32, 128, 128, 256, 512], [80, 100]),
}
_HET_B = {
    1: ([64, 128, 256, 512, 512], [100, 100]),
    2: ([64, 128, 256, 256, 256], [100, 100]),
    3: ([64, 128, 256, 256, 256], [80, 80]),
    4: ([32, 96, 256, 256, 256], [80, 80]),
    5: ([32, 96, 128, 128, 256], [80, 80]),
}


def get_spec(name: str, width: float = 1.0) -> ModelSpec:
    if name == "mlp":
        h1, h2 = _round4(100, width), _round4(64, width)
        return ModelSpec(
            name,
            width,
            (784,),
            (
                Fc(784, h1, True),
                Fc(h1, h2, True),
                Fc(h2, NUM_CLASSES, False),
            ),
        )
    if name == "cnn1":
        c1, c2 = _round4(10, width), _round4(20, width)
        # 28 -conv5v-> 24 -pool-> 12 -conv5v-> 8 -pool-> 4
        fc_in = c2 * 4 * 4
        h = _round4(50, width)
        return ModelSpec(
            name,
            width,
            (1, 28, 28),
            (
                Conv(1, c1, 5, "VALID", pool_first=True),
                Conv(c1, c2, 5, "VALID", pool_first=True),
                Fc(fc_in, h, True),
                Fc(h, NUM_CLASSES, False),
            ),
        )
    if name == "cnn2":
        c = [_round4(x, width) for x in (16, 32, 64)]
        # 32 -> 16 -> 8 -> 4 with three SAME conv+pool stages
        fc_in = c[2] * 4 * 4
        h1, h2 = _round4(500, width), _round4(100, width)
        return ModelSpec(
            name,
            width,
            (3, 32, 32),
            (
                Conv(3, c[0], 3, "SAME", pool_first=False),
                Conv(c[0], c[1], 3, "SAME", pool_first=False),
                Conv(c[1], c[2], 3, "SAME", pool_first=False),
                Fc(fc_in, h1, True),
                Fc(h1, h2, True),
                Fc(h2, NUM_CLASSES, False),
            ),
        )
    if name.startswith("het_a_"):
        conv, fc = _HET_A[int(name.split("_")[-1])]
        return _vgg_spec(name, conv, fc, width)
    if name.startswith("het_b_"):
        conv, fc = _HET_B[int(name.split("_")[-1])]
        return _vgg_spec(name, conv, fc, width)
    raise ValueError(f"unknown model {name!r}")


ALL_MODELS = (
    ["mlp", "cnn1", "cnn2"]
    + [f"het_a_{i}" for i in range(1, 6)]
    + [f"het_b_{i}" for i in range(1, 6)]
)


def param_shapes(spec: ModelSpec) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) for every parameter array. Conv weights are
    OIHW; FC weights are (in, out)."""
    shapes = []
    for i, layer in enumerate(spec.layers):
        if isinstance(layer, Conv):
            shapes.append(
                (
                    f"conv{i}_w",
                    (layer.out_ch, layer.in_ch, layer.kernel, layer.kernel),
                )
            )
            shapes.append((f"conv{i}_b", (layer.out_ch,)))
        else:
            shapes.append((f"fc{i}_w", (layer.in_dim, layer.out_dim)))
            shapes.append((f"fc{i}_b", (layer.out_dim,)))
    return shapes


def init_params(spec: ModelSpec, key) -> List[jax.Array]:
    """Init mirroring the rust registry: He-normal convs, damped FC
    weights (×0.5) with an extra ×0.2 on the classifier layer (keeps the
    deep VGG sub-models in the plain-SGD stable region; see
    EXPERIMENTS.md). Only used by python tests — rust owns runtime init.
    """
    shapes = param_shapes(spec)
    last_w = len(shapes) - 2
    params = []
    for i, (name, shape) in enumerate(shapes):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = (
                shape[1] * shape[2] * shape[3] if len(shape) == 4 else shape[0]
            )
            std = jnp.sqrt(2.0 / fan_in)
            if len(shape) == 2:
                std = std * 0.5
            if i == last_w:
                std = std * 0.2
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


# --------------------------------------------------------------------------
# Forward / loss / train / eval
# --------------------------------------------------------------------------

_DIMNUMS = ("NCHW", "OIHW", "NCHW")


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID"
    )


def forward(spec: ModelSpec, params: List[jax.Array], x: jax.Array):
    """Logits for a batch. x: [B,784] (MLP) or [B,C,H,W] (CNNs)."""
    idx = 0
    flat = False
    for layer in spec.layers:
        if isinstance(layer, Conv):
            w, b = params[idx], params[idx + 1]
            idx += 2
            x = jax.lax.conv_general_dilated(
                x, w, (1, 1), layer.padding, dimension_numbers=_DIMNUMS
            ) + b[None, :, None, None]
            if layer.pool_first:
                x = _maxpool2(x)
                x = jax.nn.relu(x)
            else:
                x = jax.nn.relu(x)
                x = _maxpool2(x)
        else:
            if not flat and x.ndim > 2:
                x = x.reshape(x.shape[0], -1)
            flat = True
            w, b = params[idx], params[idx + 1]
            idx += 2
            x = dense(x, w, b)  # L1 Pallas kernel
            if layer.relu:
                x = jax.nn.relu(x)
    return x


def loss_fn(spec: ModelSpec, params, x, y):
    """Mean softmax cross-entropy; y: int32[B]."""
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=1)
    return jnp.mean(nll)


def train_step(spec: ModelSpec, params, x, y, lr):
    """One SGD step. lr: f32[1]. Returns (*new_params, loss)."""
    loss, grads = jax.value_and_grad(partial(loss_fn, spec))(params, x, y)
    new_params = [p - lr[0] * g for p, g in zip(params, grads)]
    return tuple(new_params) + (loss,)


def train_scan(spec: ModelSpec, params, xs, ys, lr, steps: int):
    """`steps` SGD steps fused into one executable via lax.scan — the L2
    perf optimization that removes per-step host<->device round trips.
    xs: [S,B,...], ys: int32[S,B]. Returns (*new_params, mean_loss)."""

    def body(carry, batch):
        x, y = batch
        out = train_step(spec, carry, x, y, lr)
        return list(out[:-1]), out[-1]

    new_params, losses = jax.lax.scan(body, list(params), (xs, ys), length=steps)
    return tuple(new_params) + (jnp.mean(losses),)


def eval_batch(spec: ModelSpec, params, x, y):
    """Returns (loss_sum f32[], per_class_correct f32[10], per_class_count
    f32[10]) so the rust side can stream test batches and compute overall
    and per-class accuracy (Fig. 21)."""
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    y = y.astype(jnp.int32)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    onehot = jax.nn.one_hot(y, NUM_CLASSES, dtype=jnp.float32)
    correct = onehot * (pred == y)[:, None].astype(jnp.float32)
    return (
        jnp.sum(nll),
        jnp.sum(correct, axis=0),
        jnp.sum(onehot, axis=0),
    )
