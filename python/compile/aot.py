"""AOT compile path: lower every model's train/eval step and the flat
Pallas kernels to **HLO text** artifacts + a manifest.json the rust
runtime consumes.

HLO *text* (not `.serialize()`): jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (the version the `xla`
0.1.6 crate binds) rejects; the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/gen_hlo.py.

Usage (from python/):
    python -m compile.aot --out-dir ../artifacts            # default set
    python -m compile.aot --out-dir ../artifacts --paper    # + paper-width hetero
    python -m compile.aot --report                          # VMEM/MXU estimates
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import importance_flat, masked_acc, masked_fin, sgd_update

TRAIN_BATCH = 16
EVAL_BATCH = 64
KERNEL_CHUNK = 16384
SCAN_STEPS = 4

F32 = jnp.float32
I32 = jnp.int32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _x_shape(spec, batch):
    return (batch,) + tuple(spec.input_shape)


# --------------------------------------------------------------------------
# Artifact builders: each returns (lowered, manifest_entry)
# --------------------------------------------------------------------------


def build_train(spec, batch=TRAIN_BATCH):
    shapes = M.param_shapes(spec)
    args = [_sds(s) for _, s in shapes]
    args += [_sds(_x_shape(spec, batch)), _sds((batch,), I32), _sds((1,))]

    def fn(*a):
        params = list(a[: len(shapes)])
        x, y, lr = a[len(shapes) :]
        return M.train_step(spec, params, x, y, lr)

    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "train",
        "model": spec.name,
        "width": spec.width,
        "batch": batch,
        "params": [{"name": n, "shape": list(s)} for n, s in shapes],
        "inputs": [
            {"name": "x", "shape": list(_x_shape(spec, batch)), "dtype": "f32"},
            {"name": "y", "shape": [batch], "dtype": "i32"},
            {"name": "lr", "shape": [1], "dtype": "f32"},
        ],
        "outputs": [n for n, _ in shapes] + ["loss"],
    }
    return lowered, entry


def build_train_scan(spec, steps=SCAN_STEPS, batch=TRAIN_BATCH):
    shapes = M.param_shapes(spec)
    args = [_sds(s) for _, s in shapes]
    args += [
        _sds((steps,) + _x_shape(spec, batch)),
        _sds((steps, batch), I32),
        _sds((1,)),
    ]

    def fn(*a):
        params = list(a[: len(shapes)])
        xs, ys, lr = a[len(shapes) :]
        return M.train_scan(spec, params, xs, ys, lr, steps)

    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "train_scan",
        "model": spec.name,
        "width": spec.width,
        "batch": batch,
        "steps": steps,
        "params": [{"name": n, "shape": list(s)} for n, s in shapes],
        "inputs": [
            {
                "name": "xs",
                "shape": [steps] + list(_x_shape(spec, batch)),
                "dtype": "f32",
            },
            {"name": "ys", "shape": [steps, batch], "dtype": "i32"},
            {"name": "lr", "shape": [1], "dtype": "f32"},
        ],
        "outputs": [n for n, _ in shapes] + ["loss"],
    }
    return lowered, entry


def build_eval(spec, batch=EVAL_BATCH):
    shapes = M.param_shapes(spec)
    args = [_sds(s) for _, s in shapes]
    args += [_sds(_x_shape(spec, batch)), _sds((batch,), I32)]

    def fn(*a):
        params = list(a[: len(shapes)])
        x, y = a[len(shapes) :]
        return M.eval_batch(spec, params, x, y)

    lowered = jax.jit(fn).lower(*args)
    entry = {
        "kind": "eval",
        "model": spec.name,
        "width": spec.width,
        "batch": batch,
        "params": [{"name": n, "shape": list(s)} for n, s in shapes],
        "inputs": [
            {"name": "x", "shape": list(_x_shape(spec, batch)), "dtype": "f32"},
            {"name": "y", "shape": [batch], "dtype": "i32"},
        ],
        "outputs": ["loss_sum", "per_class_correct", "per_class_count"],
    }
    return lowered, entry


def build_kernels(chunk=KERNEL_CHUNK):
    out = []
    f = _sds((chunk,))
    s1 = _sds((1,))
    out.append(
        (
            "kern_masked_acc",
            jax.jit(lambda n, d, w, m, mn: masked_acc(n, d, w, m, mn)).lower(
                f, f, f, f, s1
            ),
            {
                "kind": "kernel",
                "op": "masked_acc",
                "chunk": chunk,
                "inputs": ["num", "den", "w", "mask", "mn"],
                "outputs": ["num", "den"],
            },
        )
    )
    out.append(
        (
            "kern_masked_fin",
            jax.jit(lambda n, d, p: (masked_fin(n, d, p),)).lower(f, f, f),
            {
                "kind": "kernel",
                "op": "masked_fin",
                "chunk": chunk,
                "inputs": ["num", "den", "prev"],
                "outputs": ["out"],
            },
        )
    )
    out.append(
        (
            "kern_importance",
            jax.jit(lambda w, dw: (importance_flat(w, dw),)).lower(f, f),
            {
                "kind": "kernel",
                "op": "importance",
                "chunk": chunk,
                "inputs": ["w", "dw"],
                "outputs": ["scores"],
            },
        )
    )
    out.append(
        (
            "kern_sgd",
            jax.jit(lambda w, g, lr: (sgd_update(w, g, lr),)).lower(f, f, s1),
            {
                "kind": "kernel",
                "op": "sgd",
                "chunk": chunk,
                "inputs": ["w", "g", "lr"],
                "outputs": ["w"],
            },
        )
    )
    return out


# --------------------------------------------------------------------------
# Model geometry export (cross-checked against the rust registry)
# --------------------------------------------------------------------------


def geometry(spec):
    layers = []
    for i, layer in enumerate(spec.layers):
        if isinstance(layer, M.Conv):
            layers.append(
                {
                    "kind": "conv",
                    "in": layer.in_ch,
                    "out": layer.out_ch,
                    "kernel": layer.kernel,
                    "padding": layer.padding,
                }
            )
        else:
            layers.append(
                {"kind": "fc", "in": layer.in_dim, "out": layer.out_dim}
            )
    return {
        "name": spec.name,
        "width": spec.width,
        "input_shape": list(spec.input_shape),
        "layers": layers,
        "param_count": sum(
            int(jnp.prod(jnp.array(s))) for _, s in M.param_shapes(spec)
        ),
    }


# --------------------------------------------------------------------------
# The default artifact set
# --------------------------------------------------------------------------


def default_jobs(paper: bool, hetero_width: float):
    """(name, builder) pairs. Default: homogeneous models at paper width,
    hetero sub-models at `hetero_width` (CPU-tractable); --paper adds the
    full-width hetero set."""
    jobs = []
    for name in ["mlp", "cnn1", "cnn2"]:
        spec = M.get_spec(name, 1.0)
        jobs.append((tag(spec) + "_train", lambda s=spec: build_train(s)))
        jobs.append((tag(spec) + "_eval", lambda s=spec: build_eval(s)))
    spec = M.get_spec("mlp", 1.0)
    jobs.append((tag(spec) + "_train_scan", lambda s=spec: build_train_scan(s)))
    spec = M.get_spec("cnn2", 1.0)
    jobs.append((tag(spec) + "_train_scan", lambda s=spec: build_train_scan(s)))
    widths = [hetero_width] + ([1.0] if paper else [])
    for w in widths:
        for fam in ["het_a", "het_b"]:
            for i in range(1, 6):
                spec = M.get_spec(f"{fam}_{i}", w)
                jobs.append(
                    (tag(spec) + "_train", lambda s=spec: build_train(s))
                )
                jobs.append((tag(spec) + "_eval", lambda s=spec: build_eval(s)))
    return jobs


def tag(spec) -> str:
    return f"{spec.name}_w{int(round(spec.width * 100))}"


def geometry_models(paper: bool, hetero_width: float):
    specs = [M.get_spec(n, 1.0) for n in ["mlp", "cnn1", "cnn2"]]
    widths = [hetero_width] + ([1.0] if paper else [])
    for w in widths:
        for fam in ["het_a", "het_b"]:
            for i in range(1, 6):
                specs.append(M.get_spec(f"{fam}_{i}", w))
    return specs


# --------------------------------------------------------------------------
# Goldens: deterministic input/output pairs the rust integration tests
# replay through the PJRT runtime (little-endian flat .bin + goldens.json).
# --------------------------------------------------------------------------


def _write_bin(path, arr):
    import numpy as np

    np.asarray(arr).astype(
        "<i4" if arr.dtype == jnp.int32 else "<f4"
    ).tofile(path)


def emit_goldens(out_dir: str):
    import numpy as np

    gdir = os.path.join(out_dir, "goldens")
    os.makedirs(gdir, exist_ok=True)
    rng = np.random.default_rng(42)
    goldens = []

    def record(name, inputs, outputs):
        entry = {"artifact": name, "inputs": [], "outputs": []}
        for i, a in enumerate(inputs):
            f = f"{name}_in{i}.bin"
            _write_bin(os.path.join(gdir, f), a)
            entry["inputs"].append(
                {
                    "file": f,
                    "shape": list(a.shape),
                    "dtype": "i32" if a.dtype == jnp.int32 else "f32",
                }
            )
        for i, a in enumerate(outputs):
            a = jnp.asarray(a)
            f = f"{name}_out{i}.bin"
            _write_bin(os.path.join(gdir, f), a)
            entry["outputs"].append(
                {
                    "file": f,
                    "shape": list(a.shape),
                    "dtype": "i32" if a.dtype == jnp.int32 else "f32",
                }
            )
        goldens.append(entry)

    # mlp train step
    spec = M.get_spec("mlp", 1.0)
    params = [
        jnp.asarray(rng.normal(size=s).astype(np.float32) * 0.05)
        for _, s in M.param_shapes(spec)
    ]
    x = jnp.asarray(rng.normal(size=(TRAIN_BATCH, 784)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=TRAIN_BATCH).astype(np.int32))
    lr = jnp.asarray([0.05], jnp.float32)
    outs = M.train_step(spec, params, x, y, lr)
    record("mlp_w100_train", params + [x, y, lr], list(outs))

    # mlp eval
    xe = jnp.asarray(rng.normal(size=(EVAL_BATCH, 784)).astype(np.float32))
    ye = jnp.asarray(rng.integers(0, 10, size=EVAL_BATCH).astype(np.int32))
    outs = M.eval_batch(spec, params, xe, ye)
    record("mlp_w100_eval", params + [xe, ye], list(outs))

    # kernels
    f = KERNEL_CHUNK
    num = jnp.asarray(rng.normal(size=f).astype(np.float32))
    den = jnp.abs(jnp.asarray(rng.normal(size=f).astype(np.float32)))
    w = jnp.asarray(rng.normal(size=f).astype(np.float32))
    mask = jnp.asarray((rng.random(f) < 0.5).astype(np.float32))
    mn = jnp.asarray([3.5], jnp.float32)
    record("kern_masked_acc", [num, den, w, mask, mn], list(masked_acc(num, den, w, mask, mn)))
    den0 = den * mask  # exercise the zero-coverage branch
    record("kern_masked_fin", [num, den0, w], [masked_fin(num, den0, w)])
    record("kern_importance", [w, num], [importance_flat(w, num)])
    record("kern_sgd", [w, num, mn], [sgd_update(w, num, mn)])

    with open(os.path.join(gdir, "goldens.json"), "w") as fp:
        json.dump(goldens, fp, indent=1)
    print(f"wrote {len(goldens)} goldens -> {gdir}", file=sys.stderr)


# --------------------------------------------------------------------------
# VMEM / MXU report (DESIGN.md §Hardware-Adaptation)
# --------------------------------------------------------------------------


def report():
    from .kernels import dense as _dense_mod  # noqa: F401
    from .kernels.dense import _BK, _BM, _BN

    tile_bytes = (_BM * _BK + _BK * _BN + _BM * _BN) * 4
    print(f"dense tile ({_BM},{_BK},{_BN}): VMEM/tile = {tile_bytes/1024:.1f} KiB")
    print("per-model dense-layer MXU occupancy estimate (batch=16):")
    for name in M.ALL_MODELS:
        spec = M.get_spec(name, 1.0)
        flops = 0
        pad_flops = 0
        for layer in spec.layers:
            if isinstance(layer, M.Fc):
                m, k, n = TRAIN_BATCH, layer.in_dim, layer.out_dim
                flops += 2 * m * k * n

                def up(v, b):
                    return -(-v // b) * b

                pad_flops += 2 * up(m, _BM) * up(k, _BK) * up(n, _BN)
        if pad_flops:
            print(
                f"  {name:8s} dense MACs {flops/1e6:8.2f}M "
                f"padded {pad_flops/1e6:8.2f}M  util {flops/pad_flops:5.1%}"
            )


# --------------------------------------------------------------------------
# Main
# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--paper", action="store_true", help="also emit paper-width hetero models")
    ap.add_argument("--hetero-width", type=float, default=0.25)
    ap.add_argument("--report", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated artifact-name substrings")
    args = ap.parse_args()

    if args.report:
        report()
        return

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"train_batch": TRAIN_BATCH, "eval_batch": EVAL_BATCH,
                "kernel_chunk": KERNEL_CHUNK, "artifacts": [], "models": []}

    jobs = default_jobs(args.paper, args.hetero_width)
    kernel_jobs = [(n, (lambda l=low, e=ent: (l, e))) for n, low, ent in build_kernels()]
    only = args.only.split(",") if args.only else None

    t0 = time.time()
    for name, builder in kernel_jobs + jobs:
        if only and not any(o in name for o in only):
            continue
        t1 = time.time()
        lowered, entry = builder()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entry["name"] = name
        entry["file"] = fname
        manifest["artifacts"].append(entry)
        print(f"  [{time.time()-t1:6.2f}s] {name}  ({len(text)/1024:.0f} KiB)",
              file=sys.stderr)

    for spec in geometry_models(args.paper, args.hetero_width):
        manifest["models"].append(geometry(spec))

    if not only:
        emit_goldens(args.out_dir)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts in "
          f"{time.time()-t0:.1f}s -> {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
