"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes and value distributions; every kernel must match
ref.py to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    dense,
    importance_flat,
    masked_acc,
    masked_fin,
    matmul_pallas,
    sgd_update,
)
from compile.kernels import ref

RTOL = 1e-4
ATOL = 1e-5


def _arr(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# matmul / dense
# --------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 300),
    n=st.integers(1, 200),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = _arr(rng, (m, k))
    w = _arr(rng, (k, n))
    got = matmul_pallas(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=1e-3)


@pytest.mark.parametrize(
    "m,k,n",
    [(16, 784, 100), (16, 100, 64), (16, 64, 10), (64, 1024, 500), (1, 1, 1)],
)
def test_dense_paper_shapes(m, k, n):
    rng = np.random.default_rng(0)
    x, w, b = _arr(rng, (m, k)), _arr(rng, (k, n)), _arr(rng, (n,))
    np.testing.assert_allclose(
        dense(x, w, b), ref.dense_ref(x, w, b), rtol=RTOL, atol=1e-3
    )


def test_dense_grad_matches_ref():
    rng = np.random.default_rng(1)
    x, w, b = _arr(rng, (8, 33)), _arr(rng, (33, 17)), _arr(rng, (17,))

    def f_pallas(w, b):
        return jnp.sum(jax.nn.relu(dense(x, w, b)) ** 2)

    def f_ref(w, b):
        return jnp.sum(jax.nn.relu(ref.dense_ref(x, w, b)) ** 2)

    gw, gb = jax.grad(f_pallas, argnums=(0, 1))(w, b)
    gw_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(gw, gw_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(gb, gb_r, rtol=1e-3, atol=1e-3)


def test_dense_jit_compiles():
    rng = np.random.default_rng(2)
    x, w, b = _arr(rng, (4, 12)), _arr(rng, (12, 5)), _arr(rng, (5,))
    got = jax.jit(dense)(x, w, b)
    np.testing.assert_allclose(got, ref.dense_ref(x, w, b), rtol=RTOL, atol=1e-4)


# --------------------------------------------------------------------------
# masked aggregation (Eq. 4)
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    chunks=st.integers(1, 4),
    mn=st.floats(0.01, 100.0),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_acc_matches_ref(chunks, mn, density, seed):
    rng = np.random.default_rng(seed)
    f = 1024 * chunks
    num, den, w = _arr(rng, f), jnp.abs(_arr(rng, f)), _arr(rng, f)
    mask = jnp.asarray((rng.random(f) < density).astype(np.float32))
    mn_a = jnp.asarray([mn], jnp.float32)
    gn, gd = masked_acc(num, den, w, mask, mn_a)
    wn, wd = ref.masked_acc_ref(num, den, w, mask, mn_a)
    np.testing.assert_allclose(gn, wn, rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(gd, wd, rtol=RTOL, atol=ATOL)


@settings(max_examples=15, deadline=None)
@given(
    chunks=st.integers(1, 3),
    coverage=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_fin_matches_ref(chunks, coverage, seed):
    rng = np.random.default_rng(seed)
    f = 1024 * chunks
    num, prev = _arr(rng, f), _arr(rng, f)
    den = jnp.asarray(
        (rng.random(f) < coverage).astype(np.float32) * rng.random(f).astype(np.float32)
    )
    got = masked_fin(num, den, prev)
    want = ref.masked_fin_ref(num, den, prev)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_masked_fin_zero_coverage_keeps_prev():
    f = 1024
    num = jnp.ones(f)
    den = jnp.zeros(f)
    prev = jnp.full(f, 7.25)
    np.testing.assert_array_equal(masked_fin(num, den, prev), prev)


def test_masked_acc_full_masks_equal_fedavg():
    """With all-ones masks accumulated over N clients, finalize must equal
    the plain weighted average (FedDD degenerates to FedAvg)."""
    rng = np.random.default_rng(3)
    f = 2048
    ws = [_arr(rng, f) for _ in range(5)]
    mns = [1.0, 2.0, 3.0, 4.0, 5.0]
    num, den = jnp.zeros(f), jnp.zeros(f)
    ones = jnp.ones(f)
    for w, mn in zip(ws, mns):
        num, den = masked_acc(num, den, w, ones, jnp.asarray([mn], jnp.float32))
    got = masked_fin(num, den, jnp.zeros(f))
    want = sum(w * mn for w, mn in zip(ws, mns)) / sum(mns)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# importance (Eq. 20/21) & sgd
# --------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    chunks=st.integers(1, 3),
    scale=st.floats(1e-6, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_importance_matches_ref(chunks, scale, seed):
    rng = np.random.default_rng(seed)
    f = 1024 * chunks
    w, dw = _arr(rng, f, scale), _arr(rng, f, scale * 0.1)
    got = importance_flat(w, dw)
    want = ref.importance_ref(w, dw)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


def test_importance_zero_weight_guard():
    f = 1024
    w = jnp.zeros(f)
    dw = jnp.ones(f)
    got = importance_flat(w, dw)
    assert bool(jnp.all(jnp.isfinite(got)))


@settings(max_examples=10, deadline=None)
@given(lr=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
def test_sgd_update_matches_ref(lr, seed):
    rng = np.random.default_rng(seed)
    f = 1024
    w, g = _arr(rng, f), _arr(rng, f)
    lr_a = jnp.asarray([lr], jnp.float32)
    np.testing.assert_allclose(
        sgd_update(w, g, lr_a),
        ref.sgd_update_ref(w, g, lr_a),
        rtol=1e-5,
        atol=1e-6,
    )
