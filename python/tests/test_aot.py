"""AOT artifact tests: HLO text parses, contains no TPU custom-calls
(interpret=True guarantee), manifest is consistent, and the lowered
train-step numerically matches the eager L2 function when executed
through jax's own HLO path."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_hlo_text_roundtrip_small():
    spec = M.get_spec("mlp", 0.25)
    lowered, entry = aot.build_train(spec, batch=4)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "custom-call" not in text  # would be un-runnable on CPU PJRT
    assert len(entry["params"]) == 6


def test_manifest_artifacts_exist_and_parse():
    man = _manifest()
    assert len(man["artifacts"]) >= 30
    for a in man["artifacts"]:
        path = os.path.join(ART, a["file"])
        assert os.path.exists(path), a["file"]
        with open(path) as f:
            head = f.read(4096)
        assert "HloModule" in head, a["file"]


def test_manifest_no_custom_calls():
    man = _manifest()
    for a in man["artifacts"]:
        with open(os.path.join(ART, a["file"])) as f:
            assert "custom-call" not in f.read(), a["file"]


def test_manifest_geometry_matches_registry():
    man = _manifest()
    by_name = {(m["name"], round(m["width"] * 100)): m for m in man["models"]}
    for (name, w), m in by_name.items():
        spec = M.get_spec(name, w / 100.0)
        shapes = M.param_shapes(spec)
        assert m["param_count"] == sum(int(np.prod(s)) for _, s in shapes)
        assert len(m["layers"]) == len(spec.layers)


def test_manifest_param_shapes_agree_with_registry():
    man = _manifest()
    for a in man["artifacts"]:
        if a["kind"] not in ("train", "eval"):
            continue
        spec = M.get_spec(a["model"], a["width"])
        want = [{"name": n, "shape": list(s)} for n, s in M.param_shapes(spec)]
        assert a["params"] == want, a["name"]


def test_hlo_text_parses_back():
    """The emitted text must parse with XLA's own HLO parser (the same
    parser the rust runtime's HloModuleProto::from_text_file uses)."""
    from jax._src.lib import xla_client as xc

    spec = M.get_spec("mlp", 0.25)
    lowered, _ = aot.build_train(spec, batch=4)
    text = aot.to_hlo_text(lowered)
    mod = xc._xla.hlo_module_from_text(text)
    assert "HloModule" in mod.to_string()  # round-trips


def _read_bin(path, shape, dtype):
    a = np.fromfile(path, dtype="<i4" if dtype == "i32" else "<f4")
    return jnp.asarray(a.reshape(shape))


def test_goldens_match_eager_recompute():
    """goldens/*.bin (replayed by the rust integration tests through PJRT)
    must equal an eager recomputation of the same functions."""
    gpath = os.path.join(ART, "goldens", "goldens.json")
    if not os.path.exists(gpath):
        pytest.skip("goldens not built (run `make artifacts`)")
    with open(gpath) as f:
        goldens = {g["artifact"]: g for g in json.load(f)}

    g = goldens["mlp_w100_train"]
    gdir = os.path.join(ART, "goldens")
    ins = [
        _read_bin(os.path.join(gdir, i["file"]), i["shape"], i["dtype"])
        for i in g["inputs"]
    ]
    spec = M.get_spec("mlp", 1.0)
    nparams = len(M.param_shapes(spec))
    outs = M.train_step(spec, list(ins[:nparams]), ins[-3], ins[-2], ins[-1])
    for want, o in zip(g["outputs"], outs):
        got = _read_bin(
            os.path.join(gdir, want["file"]), want["shape"], want["dtype"]
        )
        np.testing.assert_allclose(np.asarray(o), got, rtol=1e-5, atol=1e-6)


def test_kernel_artifacts_have_expected_chunk():
    man = _manifest()
    kerns = [a for a in man["artifacts"] if a["kind"] == "kernel"]
    assert {k["op"] for k in kerns} == {"masked_acc", "masked_fin", "importance", "sgd"}
    assert all(k["chunk"] == man["kernel_chunk"] for k in kerns)
