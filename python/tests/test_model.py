"""L2 model tests: registry shapes (Tables 2/3/6), forward shapes, gradient
vs finite differences, training-loss decrease, eval accounting."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def _data(spec, batch, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(batch,) + spec.input_shape).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 10, size=batch).astype(np.int32))
    return x, y


# --------------------------------------------------------------------------
# Registry / shapes
# --------------------------------------------------------------------------


def test_mlp_matches_table2():
    spec = M.get_spec("mlp")
    shapes = dict(M.param_shapes(spec))
    assert shapes["fc0_w"] == (784, 100)
    assert shapes["fc1_w"] == (100, 64)
    assert shapes["fc2_w"] == (64, 10)


def test_cnn1_matches_table2():
    spec = M.get_spec("cnn1")
    shapes = dict(M.param_shapes(spec))
    assert shapes["conv0_w"] == (10, 1, 5, 5)
    assert shapes["conv1_w"] == (20, 10, 5, 5)
    assert shapes["fc2_w"] == (320, 50)  # 20 * 4 * 4
    assert shapes["fc3_w"] == (50, 10)


def test_cnn2_matches_table2():
    spec = M.get_spec("cnn2")
    shapes = dict(M.param_shapes(spec))
    assert shapes["conv0_w"] == (16, 3, 3, 3)
    assert shapes["conv1_w"] == (32, 16, 3, 3)
    assert shapes["conv2_w"] == (64, 32, 3, 3)
    assert shapes["fc3_w"] == (1024, 500)  # 64 * 4 * 4 = paper's 1024
    assert shapes["fc4_w"] == (500, 100)
    assert shapes["fc5_w"] == (100, 10)


@pytest.mark.parametrize("i,ch", [(1, 512), (2, 512), (5, 512)])
def test_het_a_channels_match_table3(i, ch):
    spec = M.get_spec(f"het_a_{i}")
    convs = [l for l in spec.layers if isinstance(l, M.Conv)]
    assert convs[-1].out_ch == ch
    expected = M._HET_A[i][0]
    assert [c.out_ch for c in convs] == expected


@pytest.mark.parametrize("i", [1, 2, 3, 4, 5])
def test_het_b_channels_match_table6(i):
    spec = M.get_spec(f"het_b_{i}")
    convs = [l for l in spec.layers if isinstance(l, M.Conv)]
    assert [c.out_ch for c in convs] == M._HET_B[i][0]


def test_submodel_nesting_het_a():
    """HeteroFL-style: every sub-model's channel counts are <= the full
    model's, layer by layer (the structural-mask premise)."""
    full = [c.out_ch for c in M.get_spec("het_a_1").layers if isinstance(c, M.Conv)]
    for i in range(2, 6):
        sub = [c.out_ch for c in M.get_spec(f"het_a_{i}").layers if isinstance(c, M.Conv)]
        assert all(s <= f for s, f in zip(sub, full)), i


def test_width_mult_scales_hidden_not_io():
    spec = M.get_spec("cnn2", 0.25)
    shapes = dict(M.param_shapes(spec))
    assert shapes["conv0_w"][1] == 3  # input channels unscaled
    assert shapes["fc5_w"][1] == 10  # classes unscaled
    assert shapes["conv0_w"][0] == 4  # 16 * 0.25
    assert shapes["fc4_w"][1] == 28  # round(100*0.25)=25 -> next mult of 4


def test_param_count_decreases_with_submodel_index():
    def count(name):
        return sum(
            int(np.prod(s)) for _, s in M.param_shapes(M.get_spec(name))
        )

    counts = [count(f"het_b_{i}") for i in range(1, 6)]
    assert counts == sorted(counts, reverse=True)


# --------------------------------------------------------------------------
# Forward / loss / train
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["mlp", "cnn1", "cnn2"])
def test_forward_shapes(name):
    spec = M.get_spec(name, 0.25 if name == "cnn2" else 1.0)
    params = M.init_params(spec, jax.random.PRNGKey(0))
    x, _ = _data(spec, 4)
    logits = M.forward(spec, params, x)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("fam", ["het_a", "het_b"])
def test_hetero_forward_shapes(fam):
    for i in (1, 5):
        spec = M.get_spec(f"{fam}_{i}", 0.25)
        params = M.init_params(spec, jax.random.PRNGKey(i))
        x, _ = _data(spec, 2)
        assert M.forward(spec, params, x).shape == (2, 10)


def test_grad_matches_finite_difference():
    spec = M.get_spec("mlp", 0.25)
    params = M.init_params(spec, jax.random.PRNGKey(0))
    x, y = _data(spec, 8)
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(spec, p, x, y))(params)
    # probe a few coordinates of the first weight matrix
    rng = np.random.default_rng(0)
    w = np.asarray(params[0])
    eps = 1e-3
    for _ in range(4):
        i, j = rng.integers(0, w.shape[0]), rng.integers(0, w.shape[1])
        wp = w.copy()
        wp[i, j] += eps
        lp = M.loss_fn(spec, [jnp.asarray(wp)] + params[1:], x, y)
        wm = w.copy()
        wm[i, j] -= eps
        lm = M.loss_fn(spec, [jnp.asarray(wm)] + params[1:], x, y)
        fd = (lp - lm) / (2 * eps)
        np.testing.assert_allclose(grads[0][i, j], fd, rtol=0.05, atol=1e-3)


def test_train_step_decreases_loss_on_learnable_data():
    spec = M.get_spec("mlp")
    params = M.init_params(spec, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # learnable synthetic data: class prototypes + small noise
    protos = rng.normal(size=(10, 784)).astype(np.float32)
    y = np.tile(np.arange(10), 10).astype(np.int32)[:64]
    x = protos[y] + 0.1 * rng.normal(size=(64, 784)).astype(np.float32)
    x, y = jnp.asarray(x), jnp.asarray(y)
    lr = jnp.asarray([0.05], jnp.float32)
    first = float(M.loss_fn(spec, params, x, y))
    for _ in range(30):
        out = M.train_step(spec, params, x, y, lr)
        params = list(out[:-1])
    last = float(out[-1])
    assert last < first * 0.5, (first, last)


def test_train_scan_equals_repeated_train_step():
    spec = M.get_spec("mlp", 0.25)
    params = M.init_params(spec, jax.random.PRNGKey(1))
    steps = 3
    rng = np.random.default_rng(1)
    xs = jnp.asarray(rng.normal(size=(steps, 8, 784)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, 10, size=(steps, 8)).astype(np.int32))
    lr = jnp.asarray([0.01], jnp.float32)
    out_scan = M.train_scan(spec, params, xs, ys, lr, steps)
    p = params
    losses = []
    for s in range(steps):
        out = M.train_step(spec, p, xs[s], ys[s], lr)
        p = list(out[:-1])
        losses.append(out[-1])
    for a, b in zip(out_scan[:-1], p):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(out_scan[-1], jnp.mean(jnp.stack(losses)), rtol=1e-5)


def test_eval_batch_accounting():
    spec = M.get_spec("mlp", 0.25)
    params = M.init_params(spec, jax.random.PRNGKey(2))
    x, y = _data(spec, 32)
    loss_sum, correct, count = M.eval_batch(spec, params, x, y)
    assert count.shape == (10,)
    assert float(jnp.sum(count)) == 32.0
    assert bool(jnp.all(correct <= count))
    assert float(loss_sum) > 0.0
    # cross-check against direct computation
    logits = M.forward(spec, params, x)
    acc_direct = float(jnp.mean(jnp.argmax(logits, -1) == y))
    np.testing.assert_allclose(float(jnp.sum(correct)) / 32.0, acc_direct)
